//! Static kernel analysis (paper §4.3, Tables 2–4).
//!
//! Turns a parsed [`Program`] plus `-D` constant bindings into:
//! * the **loop stack** (Table 2): index variable, start, exclusive end,
//!   step, for every loop of the nest;
//! * **data sources and destinations** (Tables 3/4): every array access
//!   classified per dimension as `direct` or `relative ±offset`;
//! * the **linearized access set** (§4.5): each access as an affine
//!   function of the loop indices in *elements* of the underlying array,
//!   which is what the cache predictor consumes;
//! * **flop counts** (adds, muls, divides) of the innermost body;
//! * scalar classification: true sources, temporaries, and loop-carried
//!   scalars (the latter drive the critical-path model, e.g. Kahan).

use super::ast::*;
use super::KernelError;
use std::collections::HashMap;
use std::fmt;

/// One entry of the loop stack (paper Table 2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopInfo {
    /// Index variable name.
    pub index: String,
    /// First value of the index.
    pub start: i64,
    /// Exclusive upper bound.
    pub end: i64,
    /// Positive step.
    pub step: i64,
}

impl LoopInfo {
    /// Number of iterations this loop executes.
    pub fn trip(&self) -> i64 {
        if self.end <= self.start {
            0
        } else {
            (self.end - self.start + self.step - 1) / self.step
        }
    }
}

/// A declared array with resolved dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayInfo {
    pub name: String,
    pub ty: Type,
    /// Resolved dimension extents in elements (outermost first).
    pub dims: Vec<u64>,
    /// Row-major strides in elements (same order as `dims`).
    pub strides: Vec<u64>,
}

impl ArrayInfo {
    /// Total elements.
    pub fn elements(&self) -> u64 {
        self.dims.iter().product()
    }

    /// Total size in bytes.
    pub fn bytes(&self) -> u64 {
        self.elements() * self.ty.size()
    }
}

/// How a single dimension of an access refers to the iteration space
/// (the paper's "direct" vs "relative" classification of Tables 3/4).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DimAccess {
    /// Constant index (`xy[0][..]`), or a `-D`-bound constant.
    Direct(i64),
    /// `loop_var ± offset`.
    Relative { var: String, offset: i64 },
}

impl fmt::Display for DimAccess {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DimAccess::Direct(c) => write!(f, "direct {c}"),
            DimAccess::Relative { var, offset } => {
                if *offset == 0 {
                    write!(f, "relative {var}")
                } else if *offset > 0 {
                    write!(f, "relative {var}+{offset}")
                } else {
                    write!(f, "relative {var}{offset}")
                }
            }
        }
    }
}

/// An array access in both per-dimension form (for reporting) and
/// linearized affine form (for traffic analysis).
///
/// The linear offset of the access at iteration-space displacement
/// `delta` (one entry per loop, outer→inner) from the loop center is
/// `offset + Σ coeffs[k] * delta[k]`, in elements of the array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LinearAccess {
    /// Index into [`KernelAnalysis::arrays`].
    pub array: usize,
    /// Per-dimension classification (reporting form, Tables 3/4).
    pub dims: Vec<DimAccess>,
    /// Stride coefficient per loop variable (outer→inner), elements.
    pub coeffs: Vec<i64>,
    /// Constant part of the linearized index, elements, with the loop
    /// center at zero (direct-index contributions are folded in).
    pub offset: i64,
    /// How many times this exact access appears in the body.
    pub multiplicity: u32,
}

impl LinearAccess {
    /// Linear element offset at iteration displacement `delta`.
    pub fn offset_at(&self, delta: &[i64]) -> i64 {
        debug_assert_eq!(delta.len(), self.coeffs.len());
        self.offset + self.coeffs.iter().zip(delta).map(|(c, d)| c * d).sum::<i64>()
    }
}

/// Flop counts of one inner-loop iteration (source-level, per the paper:
/// compiler transformations like CSE are intentionally not modeled here —
/// the in-core port model applies its own codegen policies).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlopCount {
    pub adds: u32,
    pub muls: u32,
    pub divs: u32,
}

impl FlopCount {
    /// Total flops per inner iteration.
    pub fn total(&self) -> u32 {
        self.adds + self.muls + self.divs
    }
}

/// Scalar classification result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScalarUse {
    /// Read-only input (a "data source" in Table 3): `s`, `c0`, ...
    Source,
    /// Written before read within one iteration: `d`, `lap`, `prod`, ...
    Temporary,
    /// Read before written ⇒ carries a dependency across iterations
    /// (`sum`, `c` in Kahan; `s` in a scalar product).
    LoopCarried,
}

/// Full static analysis of a kernel (everything downstream stages need).
#[derive(Debug, Clone)]
pub struct KernelAnalysis {
    /// Loop stack, outermost first (Table 2).
    pub loops: Vec<LoopInfo>,
    /// Declared arrays that are actually accessed.
    pub arrays: Vec<ArrayInfo>,
    /// Array reads of one inner iteration (deduplicated, with multiplicity).
    pub reads: Vec<LinearAccess>,
    /// Array writes of one inner iteration.
    pub writes: Vec<LinearAccess>,
    /// Scalar classification by name.
    pub scalars: HashMap<String, ScalarUse>,
    /// Source-level flop counts per inner iteration.
    pub flops: FlopCount,
    /// The innermost statements (cloned for downstream IR generation).
    pub stmts: Vec<Stmt>,
    /// Dominant element type (widest across accessed arrays).
    pub element: Type,
    /// The constant bindings used.
    pub constants: HashMap<String, i64>,
}

/// Alias kept for API clarity: the per-iteration access pattern.
pub type AccessPattern = (Vec<LinearAccess>, Vec<LinearAccess>);

/// Evaluate an integer expression under constant bindings.
fn eval_int(e: &Expr, consts: &HashMap<String, i64>) -> Result<i64, KernelError> {
    match e {
        Expr::Int(v) => Ok(*v),
        Expr::Float(_) => Err(KernelError::restriction(
            "float literal where an integer is required".into(),
        )),
        Expr::Var(name) => consts
            .get(name)
            .copied()
            .ok_or_else(|| KernelError::unbound_constant(name)),
        Expr::Neg(inner) => Ok(-eval_int(inner, consts)?),
        Expr::Binary { op, lhs, rhs } => {
            let l = eval_int(lhs, consts)?;
            let r = eval_int(rhs, consts)?;
            Ok(match op {
                BinOp::Add => l + r,
                BinOp::Sub => l - r,
                BinOp::Mul => l * r,
                BinOp::Div => {
                    if r == 0 {
                        return Err(KernelError::semantic("division by zero in size expression".into()));
                    }
                    l / r
                }
            })
        }
        Expr::Index { .. } => Err(KernelError::restriction(
            "array access inside a size/bound expression".into(),
        )),
    }
}

/// Normalize an index expression to `var ± offset` or a constant, per the
/// paper's §4.3 restrictions.
fn classify_index(
    e: &Expr,
    loop_vars: &[String],
    consts: &HashMap<String, i64>,
) -> Result<DimAccess, KernelError> {
    // Try pure-constant evaluation first (covers `0`, `N/2`, bound consts).
    if let Ok(v) = eval_int(e, consts) {
        return Ok(DimAccess::Direct(v));
    }
    fn split(
        e: &Expr,
        loop_vars: &[String],
        consts: &HashMap<String, i64>,
    ) -> Result<(Option<String>, i64), KernelError> {
        match e {
            Expr::Var(name) if loop_vars.contains(name) => Ok((Some(name.clone()), 0)),
            Expr::Var(name) => consts
                .get(name)
                .map(|v| (None, *v))
                .ok_or_else(|| KernelError::unbound_constant(name)),
            Expr::Int(v) => Ok((None, *v)),
            Expr::Neg(inner) => {
                let (v, o) = split(inner, loop_vars, consts)?;
                if v.is_some() {
                    return Err(KernelError::restriction(
                        "negated loop index in array subscript".into(),
                    ));
                }
                Ok((None, -o))
            }
            Expr::Binary { op: BinOp::Add, lhs, rhs } => {
                let (lv, lo) = split(lhs, loop_vars, consts)?;
                let (rv, ro) = split(rhs, loop_vars, consts)?;
                match (lv, rv) {
                    (Some(v), None) | (None, Some(v)) => Ok((Some(v), lo + ro)),
                    (None, None) => Ok((None, lo + ro)),
                    (Some(_), Some(_)) => Err(KernelError::restriction(
                        "sum of two loop indices in array subscript".into(),
                    )),
                }
            }
            Expr::Binary { op: BinOp::Sub, lhs, rhs } => {
                let (lv, lo) = split(lhs, loop_vars, consts)?;
                let (rv, ro) = split(rhs, loop_vars, consts)?;
                match (lv, rv) {
                    (Some(v), None) => Ok((Some(v), lo - ro)),
                    (None, None) => Ok((None, lo - ro)),
                    _ => Err(KernelError::restriction(
                        "loop index on the right of a subtraction in subscript".into(),
                    )),
                }
            }
            _ => Err(KernelError::restriction(
                "array subscript must be `loop_var ± const` or a constant expression",
            )),
        }
    }
    let (var, off) = split(e, loop_vars, consts)?;
    match var {
        Some(v) => Ok(DimAccess::Relative { var: v, offset: off }),
        None => Ok(DimAccess::Direct(off)),
    }
}

impl KernelAnalysis {
    /// Run the full static analysis of `program` under `constants`.
    pub fn from_program(
        program: &Program,
        constants: &HashMap<String, i64>,
    ) -> Result<Self, KernelError> {
        // --- loop stack (Table 2) ---
        let mut loops = Vec::new();
        for l in program.loops() {
            let start = eval_int(&l.start, constants)?;
            let end = eval_int(&l.end, constants)?;
            let step = eval_int(&l.step, constants)?;
            if step <= 0 {
                return Err(KernelError::restriction(format!(
                    "loop step over '{}' must be positive, got {step}",
                    l.index
                )));
            }
            loops.push(LoopInfo { index: l.index.clone(), start, end, step });
        }
        let loop_vars: Vec<String> = loops.iter().map(|l| l.index.clone()).collect();
        {
            let mut sorted = loop_vars.clone();
            sorted.sort();
            sorted.dedup();
            if sorted.len() != loop_vars.len() {
                return Err(KernelError::semantic("duplicate loop index variable".into()));
            }
        }

        let stmts = program.inner_stmts().to_vec();

        // --- gather raw array accesses & scalar uses in statement order ---
        let mut raw: Vec<Raw> = Vec::new();
        let mut scalar_events: Vec<(String, bool)> = Vec::new(); // (name, is_write)

        fn walk_expr(e: &Expr, raw: &mut Vec<Raw>, scalars: &mut Vec<(String, bool)>) {
            match e {
                Expr::Index { array, indices } => {
                    raw.push(Raw { name: array.clone(), dims_expr: indices.clone(), write: false });
                    // index sub-expressions cannot contain data accesses
                    // (validated by classify_index later)
                }
                Expr::Var(name) => scalars.push((name.clone(), false)),
                Expr::Binary { lhs, rhs, .. } => {
                    walk_expr(lhs, raw, scalars);
                    walk_expr(rhs, raw, scalars);
                }
                Expr::Neg(inner) => walk_expr(inner, raw, scalars),
                _ => {}
            }
        }

        let mut flops = FlopCount::default();
        fn count_flops(e: &Expr, f: &mut FlopCount) {
            match e {
                Expr::Binary { op, lhs, rhs } => {
                    match op {
                        BinOp::Add | BinOp::Sub => f.adds += 1,
                        BinOp::Mul => f.muls += 1,
                        BinOp::Div => f.divs += 1,
                    }
                    count_flops(lhs, f);
                    count_flops(rhs, f);
                }
                Expr::Neg(inner) => count_flops(inner, f),
                _ => {}
            }
        }

        for st in &stmts {
            // RHS first (reads), then LHS (write) — matches C semantics.
            walk_expr(&st.rhs, &mut raw, &mut scalar_events);
            count_flops(&st.rhs, &mut flops);
            if let Some(op) = st.op.bin_op() {
                // compound assignment implies a read of the destination
                // and one extra flop
                match op {
                    BinOp::Add | BinOp::Sub => flops.adds += 1,
                    BinOp::Mul => flops.muls += 1,
                    BinOp::Div => flops.divs += 1,
                }
                match &st.lhs {
                    Expr::Index { array, indices } => raw.push(Raw {
                        name: array.clone(),
                        dims_expr: indices.clone(),
                        write: false,
                    }),
                    Expr::Var(name) => scalar_events.push((name.clone(), false)),
                    _ => unreachable!("parser enforces lhs shape"),
                }
            }
            match &st.lhs {
                Expr::Index { array, indices } => {
                    raw.push(Raw { name: array.clone(), dims_expr: indices.clone(), write: true })
                }
                Expr::Var(name) => scalar_events.push((name.clone(), true)),
                _ => unreachable!("parser enforces lhs shape"),
            }
        }

        // --- resolve arrays actually accessed ---
        let mut arrays: Vec<ArrayInfo> = Vec::new();
        let mut array_ix: HashMap<String, usize> = HashMap::new();
        let mut element = Type::Float;
        for r in &raw {
            if array_ix.contains_key(&r.name) {
                continue;
            }
            let decl = program.decl(&r.name).ok_or_else(|| {
                KernelError::semantic(format!("array '{}' used but not declared", r.name))
            })?;
            if !decl.is_array() {
                return Err(KernelError::semantic(format!(
                    "'{}' is declared scalar but indexed as array",
                    r.name
                )));
            }
            if decl.dims.len() != r.dims_expr.len() {
                return Err(KernelError::semantic(format!(
                    "array '{}' declared with {} dims but accessed with {}",
                    r.name,
                    decl.dims.len(),
                    r.dims_expr.len()
                )));
            }
            let mut dims = Vec::new();
            for (k, d) in decl.dims.iter().enumerate() {
                let extent = match d {
                    Expr::Var(v) if v == "__unbounded__" => {
                        // `double a[]`: infer the extent from the loop that
                        // indexes this dimension (max index + slack).
                        infer_unbounded_extent(&raw, &r.name, k, &loops)?
                    }
                    other => {
                        let v = eval_int(other, constants)?;
                        if v <= 0 {
                            return Err(KernelError::semantic(format!(
                                "array '{}' dimension {k} resolves to non-positive {v}",
                                r.name
                            )));
                        }
                        v as u64
                    }
                };
                dims.push(extent);
            }
            let mut strides = vec![1u64; dims.len()];
            for k in (0..dims.len().saturating_sub(1)).rev() {
                strides[k] = strides[k + 1] * dims[k + 1];
            }
            if decl.ty == Type::Double {
                element = Type::Double;
            }
            array_ix.insert(r.name.clone(), arrays.len());
            arrays.push(ArrayInfo { name: r.name.clone(), ty: decl.ty, dims, strides });
        }

        // --- linearize accesses ---
        let mut reads: Vec<LinearAccess> = Vec::new();
        let mut writes: Vec<LinearAccess> = Vec::new();
        for r in &raw {
            let aix = array_ix[&r.name];
            let info = &arrays[aix];
            let mut dims = Vec::new();
            let mut coeffs = vec![0i64; loops.len()];
            let mut offset = 0i64;
            for (k, ix_expr) in r.dims_expr.iter().enumerate() {
                let cls = classify_index(ix_expr, &loop_vars, constants)?;
                match &cls {
                    DimAccess::Direct(c) => {
                        offset += c * info.strides[k] as i64;
                    }
                    DimAccess::Relative { var, offset: o } => {
                        let li = loop_vars.iter().position(|v| v == var).ok_or_else(|| {
                            KernelError::semantic(format!("index var '{var}' is not a loop index"))
                        })?;
                        coeffs[li] += info.strides[k] as i64;
                        offset += o * info.strides[k] as i64;
                    }
                }
                dims.push(cls);
            }
            let target = if r.write { &mut writes } else { &mut reads };
            if let Some(existing) = target
                .iter_mut()
                .find(|a| a.array == aix && a.coeffs == coeffs && a.offset == offset)
            {
                existing.multiplicity += 1;
            } else {
                target.push(LinearAccess { array: aix, dims, coeffs, offset, multiplicity: 1 });
            }
        }

        // --- scalar classification ---
        let mut scalars: HashMap<String, ScalarUse> = HashMap::new();
        let mut written: Vec<String> = Vec::new();
        for (name, is_write) in &scalar_events {
            if loop_vars.contains(name) {
                continue; // loop indices are not data
            }
            if *is_write {
                if !written.contains(name) {
                    written.push(name.clone());
                }
                // keep an earlier LoopCarried / Temporary classification
                scalars.entry(name.clone()).or_insert(ScalarUse::Temporary);
                if scalars[name] == ScalarUse::Source {
                    // was read before this write ⇒ loop-carried
                    scalars.insert(name.clone(), ScalarUse::LoopCarried);
                }
            } else if !written.contains(name) {
                // read before any write in iteration order
                scalars.entry(name.clone()).or_insert(ScalarUse::Source);
            }
        }

        Ok(Self {
            loops,
            arrays,
            reads,
            writes,
            scalars,
            flops,
            stmts,
            element,
            constants: constants.clone(),
        })
    }

    /// Elements per cache line for the dominant element type.
    pub fn elements_per_cacheline(&self, cacheline_bytes: u64) -> u64 {
        cacheline_bytes / self.element.size()
    }

    /// Iterations that constitute one "unit of work" — the number of inner
    /// iterations covering exactly one cache line of stride-1 progress
    /// (paper §2.3: "a number of iterations that leads to a small integer
    /// number of cache line transfers").
    pub fn unit_of_work(&self, cacheline_bytes: u64) -> u64 {
        let inner_step = self.loops.last().map(|l| l.step).unwrap_or(1) as u64;
        let epc = self.elements_per_cacheline(cacheline_bytes).max(1);
        (epc / inner_step).max(1)
    }

    /// Total inner-loop iterations of the whole nest.
    pub fn total_iterations(&self) -> u64 {
        self.loops.iter().map(|l| l.trip().max(0) as u64).product()
    }

    /// Names of scalar data sources (Table 3's scalar rows).
    pub fn scalar_sources(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .scalars
            .iter()
            .filter(|(_, u)| **u == ScalarUse::Source)
            .map(|(n, _)| n.as_str())
            .collect();
        v.sort();
        v
    }

    /// Names of loop-carried scalars (drive the recurrence critical path).
    pub fn carried_scalars(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .scalars
            .iter()
            .filter(|(_, u)| **u == ScalarUse::LoopCarried)
            .map(|(n, _)| n.as_str())
            .collect();
        v.sort();
        v
    }

    /// Render the loop stack as the paper's Table 2.
    pub fn loop_stack_table(&self) -> String {
        let mut s = String::from("index | start | end | step\n");
        for l in &self.loops {
            s.push_str(&format!("{} | {} | {} | +{}\n", l.index, l.start, l.end, l.step));
        }
        s
    }

    /// Render data sources (Table 3) and destinations (Table 4).
    pub fn access_table(&self) -> String {
        let mut s = String::from("sources:\n");
        for a in &self.reads {
            let dims: Vec<String> = a.dims.iter().map(|d| d.to_string()).collect();
            s.push_str(&format!("  {}: [{}]\n", self.arrays[a.array].name, dims.join(", ")));
        }
        for name in self.scalar_sources() {
            s.push_str(&format!("  {name}: direct\n"));
        }
        s.push_str("destinations:\n");
        for a in &self.writes {
            let dims: Vec<String> = a.dims.iter().map(|d| d.to_string()).collect();
            s.push_str(&format!("  {}: [{}]\n", self.arrays[a.array].name, dims.join(", ")));
        }
        s
    }

    /// Bytes loaded from registers' perspective per inner iteration
    /// (reads × element size; write-allocate excluded).
    pub fn read_bytes_per_iteration(&self) -> u64 {
        self.reads
            .iter()
            .map(|a| a.multiplicity as u64 * self.arrays[a.array].ty.size())
            .sum()
    }

    /// Bytes stored per inner iteration.
    pub fn write_bytes_per_iteration(&self) -> u64 {
        self.writes
            .iter()
            .map(|a| a.multiplicity as u64 * self.arrays[a.array].ty.size())
            .sum()
    }
}

/// A raw (pre-linearization) array access gathered from the statements.
struct Raw {
    name: String,
    dims_expr: Vec<Expr>,
    write: bool,
}

/// Infer the extent of an unbounded (`[]`) array dimension from whichever
/// loop variable indexes it: loop end bound plus a cache line of slack for
/// `±offset` subscripts.
fn infer_unbounded_extent(
    raw: &[Raw],
    name: &str,
    dim: usize,
    loops: &[LoopInfo],
) -> Result<u64, KernelError> {
    for r in raw {
        if r.name != name {
            continue;
        }
        let var = match r.dims_expr.get(dim) {
            Some(Expr::Var(v)) => Some(v.clone()),
            Some(Expr::Binary { lhs, rhs, .. }) => match (lhs.as_ref(), rhs.as_ref()) {
                (Expr::Var(v), _) | (_, Expr::Var(v)) => Some(v.clone()),
                _ => None,
            },
            _ => None,
        };
        if let Some(var) = var {
            if let Some(l) = loops.iter().find(|l| l.index == var) {
                return Ok((l.end + 64).max(64) as u64);
            }
        }
    }
    Err(KernelError::semantic(format!(
        "cannot infer extent of unbounded dimension {dim} of '{name}'"
    )))
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;

    fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    #[test]
    fn jacobi_loop_stack_matches_table2() {
        // Paper Table 2: N=5000, M=500 → j: 1..499 step 1; i: 1..4999.
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 5000), ("M", 500)])).unwrap();
        assert_eq!(a.loops.len(), 2);
        assert_eq!(a.loops[0], LoopInfo { index: "j".into(), start: 1, end: 499, step: 1 });
        assert_eq!(a.loops[1], LoopInfo { index: "i".into(), start: 1, end: 4999, step: 1 });
        assert_eq!(a.loops[0].trip(), 498);
    }

    #[test]
    fn jacobi_accesses_match_tables_3_and_4() {
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 40), ("M", 40)])).unwrap();
        // 4 distinct reads of a[], 1 write of b[], scalar source s
        assert_eq!(a.reads.len(), 4);
        assert_eq!(a.writes.len(), 1);
        assert_eq!(a.scalar_sources(), vec!["s"]);
        // linearized relative offsets must be -1, +1, -N, +N
        let mut offs: Vec<i64> = a.reads.iter().map(|r| r.offset).collect();
        offs.sort();
        assert_eq!(offs, vec![-40, -1, 1, 40]);
        // write at center
        assert_eq!(a.writes[0].offset, 0);
        // coefficient check: a[j][i] has coeffs [N, 1]
        let r = a.reads.iter().find(|r| r.offset == -1).unwrap();
        assert_eq!(r.coeffs, vec![40, 1]);
    }

    #[test]
    fn jacobi_flops() {
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 40), ("M", 40)])).unwrap();
        assert_eq!(a.flops, FlopCount { adds: 3, muls: 1, divs: 0 });
    }

    #[test]
    fn kahan_scalar_classification() {
        let src = r#"
            double a[N], b[N], c;
            double sum, prod, t, y;
            for (int i = 0; i < N; ++i) {
                prod = a[i] * b[i];
                y = prod - c;
                t = sum + y;
                c = (t - sum) - y;
                sum = t;
            }
        "#;
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 1000)])).unwrap();
        let carried = a.carried_scalars();
        assert!(carried.contains(&"c"), "c is read (y=prod-c) before written");
        assert!(carried.contains(&"sum"), "sum is read (t=sum+y) before written");
        assert_eq!(a.scalars["prod"], ScalarUse::Temporary);
        assert_eq!(a.scalars["y"], ScalarUse::Temporary);
        assert_eq!(a.scalars["t"], ScalarUse::Temporary);
        // Kahan: 2 flops of the product line? prod = a*b (1 mul);
        // y (1 add), t (1 add), c (2 adds), total adds = 4
        assert_eq!(a.flops, FlopCount { adds: 4, muls: 1, divs: 0 });
    }

    #[test]
    fn triad_reads_writes() {
        let src = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 1000)])).unwrap();
        assert_eq!(a.reads.len(), 3);
        assert_eq!(a.writes.len(), 1);
        assert_eq!(a.flops, FlopCount { adds: 1, muls: 1, divs: 0 });
        assert_eq!(a.read_bytes_per_iteration(), 24);
        assert_eq!(a.write_bytes_per_iteration(), 8);
    }

    #[test]
    fn compound_assignment_counts_read_and_flop() {
        let src = "double a[N], s;\nfor (int i = 0; i < N; i++) s += a[i];";
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 100)])).unwrap();
        assert_eq!(a.flops.adds, 1);
        assert_eq!(a.scalars["s"], ScalarUse::LoopCarried);
    }

    #[test]
    fn uxx_division_detected() {
        let src = r#"
            double u1[M][N][N], d1[M][N][N], xx[M][N][N];
            double c1, c2, d, dth;
            for (int k = 2; k < M - 2; k++) {
                for (int j = 2; j < N - 2; j++) {
                    for (int i = 2; i < N - 2; i++) {
                        d = (d1[k-1][j][i] + d1[k-1][j-1][i] + d1[k][j][i] + d1[k][j-1][i]) * 0.25;
                        u1[k][j][i] = u1[k][j][i] + (dth / d) * (c1 * (xx[k][j][i] - xx[k][j][i-1]) + c2 * (xx[k][j][i+1] - xx[k][j][i-2]));
                    }
                }
            }
        "#;
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 150), ("M", 150)])).unwrap();
        assert_eq!(a.flops.divs, 1);
        assert_eq!(a.scalars["d"], ScalarUse::Temporary);
        assert!(a.scalar_sources().contains(&"dth"));
        // u1 is both read and written
        let u1_reads = a.reads.iter().filter(|r| a.arrays[r.array].name == "u1").count();
        assert_eq!(u1_reads, 1);
        assert_eq!(a.writes.len(), 1);
    }

    #[test]
    fn direct_first_dimension() {
        let src = "double xy[K][M][N];\nfor (int j = 1; j < M-1; j++) for (int i = 1; i < N-1; i++) xy[0][j][i+1] = xy[0][j][i] + 1.0;";
        let p = parse(src).unwrap();
        let a =
            KernelAnalysis::from_program(&p, &consts(&[("K", 3), ("M", 10), ("N", 20)])).unwrap();
        let w = &a.writes[0];
        assert_eq!(w.dims[0], DimAccess::Direct(0));
        assert!(matches!(&w.dims[2], DimAccess::Relative { var, offset: 1 } if var == "i"));
    }

    #[test]
    fn multiplicity_deduplicates_repeated_access() {
        let src = "double a[N], b[N];\nfor (int i = 0; i < N; i++) b[i] = a[i] * a[i];";
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 64)])).unwrap();
        assert_eq!(a.reads.len(), 1);
        assert_eq!(a.reads[0].multiplicity, 2);
    }

    #[test]
    fn unbound_constant_reported() {
        let p = parse(JACOBI).unwrap();
        let err = KernelAnalysis::from_program(&p, &consts(&[("N", 100)])).unwrap_err();
        assert_eq!(err.code(), "E201");
        assert!(err.to_string().contains("unbound constant 'M'"), "{err}");
    }

    #[test]
    fn rejects_nonaffine_subscript() {
        let src = "double a[N];\nfor (int i = 0; i < N; i++) a[i*2] = 1.0;";
        let p = parse(src).unwrap();
        assert!(KernelAnalysis::from_program(&p, &consts(&[("N", 100)])).is_err());
    }

    #[test]
    fn rejects_two_indices_in_one_subscript() {
        let src = "double a[N][N];\nfor (int j = 0; j < N; j++) for (int i = 0; i < N; i++) a[0][i+j] = 1.0;";
        let p = parse(src).unwrap();
        assert!(KernelAnalysis::from_program(&p, &consts(&[("N", 100)])).is_err());
    }

    #[test]
    fn unit_of_work_is_one_cacheline() {
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 40), ("M", 40)])).unwrap();
        assert_eq!(a.unit_of_work(64), 8); // 8 doubles per 64B line
    }

    #[test]
    fn strides_row_major() {
        let src = "double u[K][M][N];\nfor (int k=1;k<K-1;k++) for (int j=1;j<M-1;j++) for (int i=1;i<N-1;i++) u[k][j][i] = u[k-1][j][i] + 1.0;";
        let p = parse(src).unwrap();
        let a =
            KernelAnalysis::from_program(&p, &consts(&[("K", 4), ("M", 5), ("N", 6)])).unwrap();
        assert_eq!(a.arrays[0].strides, vec![30, 6, 1]);
        let r = &a.reads[0];
        assert_eq!(r.offset, -30); // u[k-1][j][i]
        assert_eq!(r.coeffs, vec![30, 6, 1]);
    }

    #[test]
    fn loop_stack_table_renders() {
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 5000), ("M", 500)])).unwrap();
        let t = a.loop_stack_table();
        assert!(t.contains("j | 1 | 499 | +1"));
        assert!(t.contains("i | 1 | 4999 | +1"));
    }

    #[test]
    fn access_table_renders_relative_notation() {
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 5000), ("M", 500)])).unwrap();
        let t = a.access_table();
        assert!(t.contains("relative j"), "{t}");
        assert!(t.contains("relative i-1"), "{t}");
        assert!(t.contains("relative i+1"), "{t}");
        assert!(t.contains("s: direct"), "{t}");
    }
}
