//! Abstract syntax tree for the restricted kernel language.

use std::fmt;

/// Floating-point element type of a declared variable (paper supports
/// `double`; `float` is the "single precision" extension listed as future
/// work in §7 — we implement it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Type {
    Double,
    Float,
}

impl Type {
    /// Element size in bytes.
    pub fn size(self) -> u64 {
        match self {
            Type::Double => 8,
            Type::Float => 4,
        }
    }
}

impl fmt::Display for Type {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Type::Double => write!(f, "double"),
            Type::Float => write!(f, "float"),
        }
    }
}

/// Binary arithmetic operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl fmt::Display for BinOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let c = match self {
            BinOp::Add => '+',
            BinOp::Sub => '-',
            BinOp::Mul => '*',
            BinOp::Div => '/',
        };
        write!(f, "{c}")
    }
}

/// Assignment operator on statements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignOp {
    /// `=`
    Set,
    /// `+=`
    Add,
    /// `-=`
    Sub,
    /// `*=`
    Mul,
    /// `/=`
    Div,
}

impl AssignOp {
    /// The arithmetic op a compound assignment implies, if any.
    pub fn bin_op(self) -> Option<BinOp> {
        match self {
            AssignOp::Set => None,
            AssignOp::Add => Some(BinOp::Add),
            AssignOp::Sub => Some(BinOp::Sub),
            AssignOp::Mul => Some(BinOp::Mul),
            AssignOp::Div => Some(BinOp::Div),
        }
    }
}

/// Expression.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Scalar variable or symbolic constant reference.
    Var(String),
    /// Array element access `name[e0][e1]...`.
    Index { array: String, indices: Vec<Expr> },
    /// Binary arithmetic.
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
    /// Unary negation.
    Neg(Box<Expr>),
}

impl Expr {
    /// Walk the expression tree, calling `f` on every node (pre-order).
    pub fn visit<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        f(self);
        match self {
            Expr::Binary { lhs, rhs, .. } => {
                lhs.visit(f);
                rhs.visit(f);
            }
            Expr::Neg(e) => e.visit(f),
            Expr::Index { indices, .. } => {
                for ix in indices {
                    ix.visit(f);
                }
            }
            _ => {}
        }
    }
}

/// A single assignment statement in the innermost loop body.
#[derive(Debug, Clone, PartialEq)]
pub struct Stmt {
    /// Destination: `Expr::Var` (scalar) or `Expr::Index` (array element).
    pub lhs: Expr,
    pub op: AssignOp,
    pub rhs: Expr,
}

/// One `for` loop header. `end` is the *exclusive* upper bound expression
/// (a `<=` comparison is normalized to `< end+1` by the parser).
#[derive(Debug, Clone, PartialEq)]
pub struct Loop {
    /// Index variable name.
    pub index: String,
    /// Start expression (must evaluate to an integer after binding).
    pub start: Expr,
    /// Exclusive end expression.
    pub end: Expr,
    /// Step expression (`++i`/`i++` lower to `1`, `i += k` to `k`).
    /// Must evaluate to a positive integer once constants are bound —
    /// checked by the analysis, which also does the evaluation.
    pub step: Expr,
    /// Body: either exactly one nested loop or the innermost statements.
    pub body: LoopBody,
}

/// Loop body alternative.
#[derive(Debug, Clone, PartialEq)]
pub enum LoopBody {
    /// Single nested loop (perfect nest, per the paper's restrictions).
    Nest(Box<Loop>),
    /// Innermost statements.
    Stmts(Vec<Stmt>),
}

/// One declared variable.
#[derive(Debug, Clone, PartialEq)]
pub struct Decl {
    pub name: String,
    pub ty: Type,
    /// Empty for scalars; dimension expressions for arrays. Dimension
    /// expressions must evaluate to positive integers after constant
    /// binding (`N`, `M+3`, `5000`, ...).
    pub dims: Vec<Expr>,
    /// Optional scalar initializer (value is irrelevant to the analysis;
    /// retained for benchmark-code generation).
    pub init: Option<f64>,
}

impl Decl {
    /// True if this declares an array.
    pub fn is_array(&self) -> bool {
        !self.dims.is_empty()
    }
}

/// A parsed kernel: declarations followed by one loop nest.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub decls: Vec<Decl>,
    pub nest: Loop,
}

impl Program {
    /// All loops of the nest, outermost first.
    pub fn loops(&self) -> Vec<&Loop> {
        let mut out = Vec::new();
        let mut cur = &self.nest;
        loop {
            out.push(cur);
            match &cur.body {
                LoopBody::Nest(inner) => cur = inner,
                LoopBody::Stmts(_) => break,
            }
        }
        out
    }

    /// The innermost statement list.
    pub fn inner_stmts(&self) -> &[Stmt] {
        let mut cur = &self.nest;
        loop {
            match &cur.body {
                LoopBody::Nest(inner) => cur = inner,
                LoopBody::Stmts(s) => return s,
            }
        }
    }

    /// Look up a declaration by name.
    pub fn decl(&self, name: &str) -> Option<&Decl> {
        self.decls.iter().find(|d| d.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_sizes() {
        assert_eq!(Type::Double.size(), 8);
        assert_eq!(Type::Float.size(), 4);
    }

    #[test]
    fn assign_op_maps_to_binop() {
        assert_eq!(AssignOp::Add.bin_op(), Some(BinOp::Add));
        assert_eq!(AssignOp::Set.bin_op(), None);
    }

    #[test]
    fn visit_reaches_all_nodes() {
        let e = Expr::Binary {
            op: BinOp::Add,
            lhs: Box::new(Expr::Var("x".into())),
            rhs: Box::new(Expr::Neg(Box::new(Expr::Int(3)))),
        };
        let mut count = 0;
        e.visit(&mut |_| count += 1);
        assert_eq!(count, 4);
    }
}
