//! PJRT runtime: load AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from Rust.
//!
//! This is the only place the `xla` crate is touched, and that crate
//! (xla_extension bindings) is **not** part of the offline toolchain — so
//! the real runtime is gated behind the `pjrt` cargo feature. Without the
//! feature, [`Runtime`] and [`LoadedKernel`] compile to stubs whose
//! constructors return a clear error, keeping every caller
//! (`bench_mode::run_pjrt`, the CLI `--bench-path pjrt`) compiling and
//! failing loudly at runtime instead of silently at build time. Enable
//! the feature by adding an `xla` dependency alongside
//! `--features pjrt`.
//!
//! The interchange format is HLO *text* — xla_extension 0.5.1 rejects the
//! 64-bit instruction ids jax ≥ 0.5 puts into serialized protos, while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//! Python never runs on this path: once `artifacts/` exists the binary
//! is self-contained.

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Metadata of one artifact, parsed from `artifacts/manifest.tsv`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Kernel sweeps fused into one executable invocation.
    pub reps: u64,
    /// Inner-loop iterations per sweep.
    pub iters_per_sweep: u64,
    /// Source flops per inner iteration.
    pub flops_per_iter: u64,
    /// Input specs: (dtype, dims) — dims empty for scalars.
    pub inputs: Vec<(String, Vec<usize>)>,
}

impl ArtifactMeta {
    /// Total inner iterations one execution performs.
    pub fn iterations_per_exec(&self) -> u64 {
        self.reps * self.iters_per_sweep
    }
}

/// Parse `manifest.tsv`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
    let mut out = Vec::new();
    for (ix, line) in text.lines().enumerate() {
        if ix == 0 || line.trim().is_empty() {
            continue; // header
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 6 {
            bail!("manifest line {} has {} columns, expected 6", ix + 1, cols.len());
        }
        let inputs = cols[5]
            .split(';')
            .map(|spec| -> Result<(String, Vec<usize>)> {
                let (dt, dims) = spec
                    .split_once(':')
                    .ok_or_else(|| anyhow!("bad input spec '{spec}'"))?;
                let dims: Vec<usize> = if dims.is_empty() {
                    vec![]
                } else {
                    dims.split(',')
                        .map(|d| d.parse().map_err(|_| anyhow!("bad dim '{d}'")))
                        .collect::<Result<_>>()?
                };
                Ok((dt.to_string(), dims))
            })
            .collect::<Result<_>>()?;
        out.push(ArtifactMeta {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            reps: cols[2].parse().context("reps")?,
            iters_per_sweep: cols[3].parse().context("iters")?,
            flops_per_iter: cols[4].parse().context("flops")?,
            inputs,
        });
    }
    Ok(out)
}

/// Timing result of repeated executions.
#[derive(Debug, Clone)]
pub struct ExecTiming {
    /// Median wall time per execution in nanoseconds.
    pub median_ns: f64,
    /// All samples (ns).
    pub samples_ns: Vec<f64>,
    /// Inner iterations per execution.
    pub iterations: u64,
}

impl ExecTiming {
    /// Iterations per second.
    pub fn iterations_per_second(&self) -> f64 {
        self.iterations as f64 / (self.median_ns / 1e9)
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::{ArtifactMeta, ExecTiming};
    use crate::util::{median, monotonic_ns};
    use anyhow::{anyhow, bail, Result};
    use std::path::{Path, PathBuf};

    /// A PJRT CPU runtime holding compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One loaded artifact, compiled and ready to execute.
    pub struct LoadedKernel {
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime { client })
        }

        /// Name of the PJRT platform backing this runtime.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile one artifact.
        pub fn load(&self, dir: &Path, meta: &ArtifactMeta) -> Result<LoadedKernel> {
            let path: PathBuf = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
            Ok(LoadedKernel { meta: meta.clone(), exe })
        }

        /// Load every artifact in a directory.
        pub fn load_all(&self, dir: &Path) -> Result<Vec<LoadedKernel>> {
            super::load_manifest(dir)?
                .iter()
                .map(|m| self.load(dir, m))
                .collect()
        }
    }

    impl LoadedKernel {
        /// Build deterministic pseudo-random inputs matching the manifest.
        pub fn make_inputs(&self, seed: u64) -> Result<Vec<xla::Literal>> {
            let mut rng = crate::util::XorShift64::new(seed | 1);
            self.meta
                .inputs
                .iter()
                .map(|(dtype, dims)| -> Result<xla::Literal> {
                    let n: usize = dims.iter().product::<usize>().max(1);
                    match dtype.as_str() {
                        "float64" => {
                            let data: Vec<f64> =
                                (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
                            let lit = xla::Literal::vec1(&data);
                            if dims.is_empty() {
                                // scalar: reshape 1-element vector to rank 0
                                lit.reshape(&[]).map_err(|e| anyhow!("{e:?}"))
                            } else {
                                let shape: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
                                lit.reshape(&shape).map_err(|e| anyhow!("{e:?}"))
                            }
                        }
                        "float32" => {
                            let data: Vec<f32> =
                                (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
                            let lit = xla::Literal::vec1(&data);
                            if dims.is_empty() {
                                lit.reshape(&[]).map_err(|e| anyhow!("{e:?}"))
                            } else {
                                let shape: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
                                lit.reshape(&shape).map_err(|e| anyhow!("{e:?}"))
                            }
                        }
                        other => bail!("unsupported artifact dtype {other}"),
                    }
                })
                .collect()
        }

        /// Execute once, returning the first output literal (tuples unpacked).
        pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
            let result = self
                .exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("executing {}: {e:?}", self.meta.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e:?}"))?;
            // aot.py lowers with return_tuple=True ⇒ unwrap the 1-tuple
            lit.to_tuple1().map_err(|e| anyhow!("untupling: {e:?}"))
        }

        /// Time `samples` executions (after one warm-up) and report medians.
        pub fn time(&self, samples: usize) -> Result<ExecTiming> {
            let inputs = self.make_inputs(0xD00D)?;
            let _warm = self.execute(&inputs)?;
            let mut times = Vec::with_capacity(samples);
            for _ in 0..samples.max(1) {
                let t0 = monotonic_ns();
                let _out = self.execute(&inputs)?;
                let t1 = monotonic_ns();
                times.push((t1 - t0) as f64);
            }
            Ok(ExecTiming {
                median_ns: median(&times),
                samples_ns: times,
                iterations: self.meta.iterations_per_exec(),
            })
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{ArtifactMeta, ExecTiming};
    use anyhow::{bail, Result};
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this build has no `pjrt` feature \
         (the xla/xla_extension crate is not part of the offline toolchain). \
         Rebuild with `cargo build --features pjrt` and an `xla` dependency \
         to execute AOT artifacts; the `virtual` and `native` bench paths \
         work without it";

    /// Stub runtime (built without the `pjrt` feature): construction fails
    /// with an actionable message.
    pub struct Runtime {
        _private: (),
    }

    /// Stub loaded artifact — never constructed without the feature.
    pub struct LoadedKernel {
        pub meta: ArtifactMeta,
    }

    impl Runtime {
        /// Always errors in this build; see the module docs.
        pub fn cpu() -> Result<Runtime> {
            bail!(UNAVAILABLE);
        }

        /// Stub platform name.
        pub fn platform(&self) -> String {
            "unavailable (no pjrt feature)".to_string()
        }

        /// Always errors in this build.
        pub fn load(&self, _dir: &Path, _meta: &ArtifactMeta) -> Result<LoadedKernel> {
            bail!(UNAVAILABLE);
        }

        /// Always errors in this build.
        pub fn load_all(&self, _dir: &Path) -> Result<Vec<LoadedKernel>> {
            bail!(UNAVAILABLE);
        }
    }

    impl LoadedKernel {
        /// Always errors in this build.
        pub fn time(&self, _samples: usize) -> Result<ExecTiming> {
            bail!(UNAVAILABLE);
        }
    }
}

pub use imp::{LoadedKernel, Runtime};

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_parses_when_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let metas = load_manifest(&dir).unwrap();
        assert_eq!(metas.len(), 5);
        let jac = metas.iter().find(|m| m.name == "jacobi2d").unwrap();
        assert_eq!(jac.inputs.len(), 2);
        assert!(jac.inputs[1].1.is_empty(), "scalar s");
        assert_eq!(jac.flops_per_iter, 4);
    }

    #[test]
    fn manifest_rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("kerncraft_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), "header\nbad line without tabs\n").unwrap();
        assert!(load_manifest(&dir).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = Runtime::cpu().unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    // The full load-execute path is covered by `rust/tests/runtime_e2e.rs`
    // (feature-gated: it needs the PJRT client, which we only want to spin
    // up once and only in `--features pjrt` builds).
}
