//! Roofline model construction (paper §2.2, §4.6.1).
//!
//! Two in-core variants, exactly as Kerncraft's modes:
//! * [`RooflineMode::Peak`] ("Roofline"): theoretical MULT+ADD peak,
//!   with the L1 cache as an additional bandwidth bottleneck;
//! * [`RooflineMode::PortModel`] ("RooflineIACA" in the paper): the port
//!   model provides the in-core time, L1 is covered by T_nOL.
//!
//! Every memory link is a candidate bottleneck: its predicted data volume
//! over the *measured* bandwidth of the closest-matching microbenchmark
//! in that level (with the requested core count) gives a time bound; the
//! largest bound wins (single-bottleneck model).

use crate::cache::TrafficPrediction;
use crate::incore::PortModel;
use crate::kernel::KernelAnalysis;
use crate::machine::MachineModel;
use anyhow::{bail, Result};

/// In-core flavour of the Roofline model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RooflineMode {
    /// Arithmetic peak performance (no compiler/IACA required).
    Peak,
    /// Port-model in-core prediction (the paper's RooflineIACA).
    PortModel,
}

/// One candidate bottleneck row of the Roofline report (paper Listing 5).
#[derive(Debug, Clone)]
pub struct RooflineBottleneck {
    /// "CPU", "L1", "L1-L2", "L2-L3", "L3-MEM".
    pub level: String,
    /// Predicted time in cycles per cache line of work.
    pub cycles: f64,
    /// Bandwidth assumed (bytes/s), None for the CPU row.
    pub bandwidth_bs: Option<f64>,
    /// Matched microbenchmark, None for the CPU row.
    pub benchmark: Option<String>,
    /// Arithmetic intensity at this level (flop/byte), None for CPU.
    pub arith_intensity: Option<f64>,
}

/// Assembled Roofline model.
#[derive(Debug, Clone)]
pub struct RooflineModel {
    pub mode: RooflineMode,
    pub bottlenecks: Vec<RooflineBottleneck>,
    /// Iterations per unit of work.
    pub iterations_per_cl: u64,
    /// Flops per unit of work.
    pub flops_per_cl: f64,
    pub clock_hz: f64,
    /// Cores assumed for bandwidth measurements.
    pub cores: u32,
}

impl RooflineModel {
    /// Build with the default single-core setting. `incore = Some` ⇒
    /// RooflineIACA flavour, `None` ⇒ arithmetic-peak flavour.
    pub fn build(
        analysis: &KernelAnalysis,
        traffic: &TrafficPrediction,
        machine: &MachineModel,
        incore: Option<&PortModel>,
    ) -> Result<RooflineModel> {
        Self::build_cores(analysis, traffic, machine, incore, 1)
    }

    /// Build for `cores` active cores (paper `--cores`).
    pub fn build_cores(
        analysis: &KernelAnalysis,
        traffic: &TrafficPrediction,
        machine: &MachineModel,
        incore: Option<&PortModel>,
        cores: u32,
    ) -> Result<RooflineModel> {
        let cl = machine.cacheline_bytes as f64;
        let cores = cores.max(1);
        let iterations_per_cl = traffic.unit_iterations;
        let flops_per_cl = analysis.flops.total() as f64 * iterations_per_cl as f64;
        let mut bottlenecks = Vec::new();

        // --- CPU row ---
        let (mode, cpu_cycles) = match incore {
            Some(pm) => (RooflineMode::PortModel, pm.t_ol.max(pm.t_nol)),
            None => {
                // theoretical peak: flops per CL over peak flops/cy,
                // assuming the ideal ADD/MUL mix the hardware offers
                let peak = match analysis.element {
                    crate::kernel::Type::Double => machine.flops_per_cycle_dp.total,
                    crate::kernel::Type::Float => machine.flops_per_cycle_sp.total,
                };
                if peak <= 0.0 {
                    bail!("machine file lacks peak flop rates");
                }
                (RooflineMode::Peak, flops_per_cl / peak)
            }
        };
        // single-core CPU capability scales with cores for chip-level use
        bottlenecks.push(RooflineBottleneck {
            level: "CPU".to_string(),
            cycles: cpu_cycles / cores as f64,
            bandwidth_bs: None,
            benchmark: None,
            arith_intensity: None,
        });

        // --- L1 row (Peak mode only: register↔L1 traffic as bandwidth) ---
        if mode == RooflineMode::Peak {
            let bytes_per_cl = (analysis.read_bytes_per_iteration()
                + analysis.write_bytes_per_iteration()) as f64
                * iterations_per_cl as f64;
            // L1 streams ≈ the kernel's full stream mix
            let sig = full_stream_signature(analysis);
            let bench = machine
                .benchmarks
                .closest_kernel(&sig)
                .ok_or_else(|| anyhow::anyhow!("no benchmark kernels"))?;
            if let Some(bw) = machine.benchmarks.bandwidth("L1", &bench.name, 1) {
                let bw_total = bw * cores as f64; // L1 is per-core
                bottlenecks.push(RooflineBottleneck {
                    level: "L1".to_string(),
                    cycles: bytes_per_cl / bw_total * machine.clock_hz,
                    bandwidth_bs: Some(bw_total),
                    benchmark: Some(bench.name.clone()),
                    arith_intensity: Some(flops_per_cl / bytes_per_cl),
                });
            }
        }

        // --- memory-link rows ---
        let n = traffic.levels.len();
        for (ix, lt) in traffic.levels.iter().enumerate() {
            let outer_name = if ix + 1 < n {
                traffic.levels[ix + 1].level.clone()
            } else {
                "MEM".to_string()
            };
            let label = format!("{}-{}", lt.level, outer_name);
            let bytes = lt.total_lines() * cl;
            if bytes <= 0.0 {
                continue;
            }
            let bench = machine
                .benchmarks
                .closest_kernel(&lt.miss_streams)
                .ok_or_else(|| anyhow::anyhow!("no benchmark kernels"))?;
            let Some(mut bw) = machine.benchmarks.bandwidth(&outer_name, &bench.name, cores)
            else {
                continue;
            };
            // private caches scale with the core count
            if let Some(lvl) = machine.level(&outer_name) {
                if lvl.cores_per_group <= 1 {
                    bw *= cores as f64;
                }
            }
            bottlenecks.push(RooflineBottleneck {
                level: label,
                cycles: bytes / bw * machine.clock_hz,
                bandwidth_bs: Some(bw),
                benchmark: Some(bench.name.clone()),
                arith_intensity: Some(flops_per_cl / bytes),
            });
        }

        Ok(RooflineModel {
            mode,
            bottlenecks,
            iterations_per_cl,
            flops_per_cl,
            clock_hz: machine.clock_hz,
            cores,
        })
    }

    /// Index of the binding bottleneck in `bottlenecks` (largest time
    /// bound; ties keep the last row) — the single source of the
    /// tie-breaking rule, also used by the serializable report.
    pub fn bottleneck_index(&self) -> usize {
        self.bottlenecks
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cycles.partial_cmp(&b.1.cycles).unwrap())
            .map(|(ix, _)| ix)
            .expect("at least the CPU row exists")
    }

    /// The binding bottleneck (largest time bound).
    pub fn bottleneck(&self) -> &RooflineBottleneck {
        &self.bottlenecks[self.bottleneck_index()]
    }

    /// The Roofline prediction in cycles per cache line of work.
    pub fn prediction(&self) -> f64 {
        self.bottleneck().cycles
    }

    /// Whether the kernel is bound by data transfers rather than compute.
    pub fn is_memory_bound(&self) -> bool {
        self.bottleneck().level != "CPU"
    }
}

/// Stream signature of the whole kernel (used for the L1 row).
fn full_stream_signature(analysis: &KernelAnalysis) -> crate::machine::StreamSig {
    use std::collections::HashSet;
    let written: HashSet<usize> = analysis.writes.iter().map(|w| w.array).collect();
    let read: HashSet<usize> = analysis.reads.iter().map(|r| r.array).collect();
    let mut sig = crate::machine::StreamSig { reads: 0, read_writes: 0, writes: 0 };
    for a in 0..analysis.arrays.len() {
        match (read.contains(&a), written.contains(&a)) {
            (true, true) => sig.read_writes += 1,
            (true, false) => sig.reads += 1,
            (false, true) => sig.writes += 1,
            (false, false) => {}
        }
    }
    sig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CachePredictor;
    use crate::incore::CodegenPolicy;
    use crate::kernel::parse;
    use std::collections::HashMap;

    fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    fn build(
        src: &str,
        c: &[(&str, i64)],
        machine: &MachineModel,
        with_incore: bool,
        cores: u32,
    ) -> RooflineModel {
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(c)).unwrap();
        let t = CachePredictor::with_cores(machine, cores).predict(&a).unwrap();
        let pm = if with_incore {
            Some(PortModel::analyze(&a, machine, &CodegenPolicy::for_machine(machine)).unwrap())
        } else {
            None
        };
        RooflineModel::build_cores(&a, &t, machine, pm.as_ref(), cores).unwrap()
    }

    #[test]
    fn jacobi_snb_roofline_matches_listing5() {
        // Paper Listing 5 / Table 5: single-core Roofline = 29.8 cy/CL,
        // bound by L3-MEM with the copy benchmark at 17.4 GB/s.
        let m = MachineModel::snb();
        let r = build(JACOBI, &[("N", 6000), ("M", 6000)], &m, true, 1);
        let b = r.bottleneck();
        assert_eq!(b.level, "L3-MEM");
        assert_eq!(b.benchmark.as_deref(), Some("copy"));
        assert!((r.prediction() - 29.8).abs() < 0.3, "pred = {}", r.prediction());
        assert!(r.is_memory_bound());
        // arithmetic intensity ≈ 0.17 flop/B (4 flops×8 / 192 B)
        assert!((b.arith_intensity.unwrap() - 0.1667).abs() < 0.01);
    }

    #[test]
    fn jacobi_hsw_roofline_matches_table5() {
        // Paper: 26.6 cy/CL on Haswell.
        let m = MachineModel::hsw();
        let r = build(JACOBI, &[("N", 6000), ("M", 6000)], &m, true, 1);
        assert!((r.prediction() - 26.6).abs() < 0.4, "pred = {}", r.prediction());
    }

    #[test]
    fn kahan_roofline_is_cpu_bound() {
        // Paper: Roofline = ECM = 96 cy/CL (T_OL dominates).
        let src = r#"
            double a[N], b[N], c;
            double sum, prod, t, y;
            for (int i = 0; i < N; ++i) {
                prod = a[i] * b[i]; y = prod - c;
                t = sum + y; c = (t - sum) - y; sum = t;
            }
        "#;
        for m in [MachineModel::snb(), MachineModel::hsw()] {
            let r = build(src, &[("N", 8000000)], &m, true, 1);
            assert_eq!(r.prediction(), 96.0, "{}", m.arch);
            assert!(!r.is_memory_bound());
        }
    }

    #[test]
    fn triad_roofline_matches_table5() {
        // Paper SNB 54.3 cy/CL, HSW 46.4 cy/CL (single-core, in-memory).
        let src = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";
        let m = MachineModel::snb();
        let r = build(src, &[("N", 8000000)], &m, true, 1);
        assert!((r.prediction() - 54.3).abs() < 0.8, "SNB pred = {}", r.prediction());
        let h = MachineModel::hsw();
        let r = build(src, &[("N", 8000000)], &h, true, 1);
        assert!((r.prediction() - 46.4).abs() < 0.8, "HSW pred = {}", r.prediction());
    }

    #[test]
    fn peak_mode_has_l1_row() {
        let m = MachineModel::snb();
        let r = build(JACOBI, &[("N", 6000), ("M", 6000)], &m, false, 1);
        assert_eq!(r.mode, RooflineMode::Peak);
        assert!(r.bottlenecks.iter().any(|b| b.level == "L1"));
        // peak CPU time: 32 flops / 8 flops/cy = 4 cy — optimistic
        let cpu = r.bottlenecks.iter().find(|b| b.level == "CPU").unwrap();
        assert_eq!(cpu.cycles, 4.0);
    }

    #[test]
    fn multicore_bandwidth_saturation() {
        // 8 cores: memory bandwidth saturates; roofline drops below the
        // single-core time but stays bandwidth-limited.
        let m = MachineModel::snb();
        let r1 = build(JACOBI, &[("N", 6000), ("M", 6000)], &m, true, 1);
        let r8 = build(JACOBI, &[("N", 6000), ("M", 6000)], &m, true, 8);
        assert!(r8.prediction() < r1.prediction());
        assert!(r8.is_memory_bound());
        // saturated bandwidth ⇒ ≈ 3 CL × 64 B at 40.8 GB/s ≈ 12.7 cy
        assert!((r8.prediction() - 12.7).abs() < 0.4, "pred = {}", r8.prediction());
    }

    #[test]
    fn roofline_never_exceeds_sum_of_parts() {
        // single-bottleneck optimism: prediction == max of rows
        let m = MachineModel::snb();
        let r = build(JACOBI, &[("N", 6000), ("M", 6000)], &m, true, 1);
        let max = r.bottlenecks.iter().map(|b| b.cycles).fold(0.0, f64::max);
        assert_eq!(r.prediction(), max);
    }
}
