//! Performance model construction (paper §2.2, §2.3, §4.6): the
//! Execution-Cache-Memory model, the Roofline model (with either the
//! port-model in-core prediction or the arithmetic-peak in-core
//! prediction), multicore scaling, and the paper's published reference
//! values for Table 5.
//!
//! The models here are *analytic*; the paper stresses they are only
//! trustworthy once validated against measurement. The
//! [`crate::session::ModelKind::Validate`] request mode closes that loop
//! by running the trace-driven testbed ([`crate::sim`]) next to the ECM
//! assembly built from this module (see DESIGN.md §1).

pub mod ecm;
pub mod reference;
pub mod roofline;
pub mod scaling;

pub use ecm::EcmModel;
pub use roofline::{RooflineBottleneck, RooflineMode, RooflineModel};
pub use scaling::ScalingModel;

/// Output units supported by the CLI (paper §4.6.1: cy/CL, It/s, FLOP/s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Cycles per cache line of work (the models' native unit).
    CyPerCl,
    /// Inner-loop iterations per second.
    ItPerS,
    /// Floating-point operations per second.
    FlopPerS,
}

impl Unit {
    /// Parse a `--unit` argument (case-insensitive: `cy/CL`, `It/s`,
    /// `FLOP/s`, plus the `FLOPs` shorthand).
    pub fn parse(s: &str) -> Option<Unit> {
        match s.to_ascii_lowercase().as_str() {
            "cy/cl" => Some(Unit::CyPerCl),
            "it/s" => Some(Unit::ItPerS),
            "flop/s" | "flops" => Some(Unit::FlopPerS),
            _ => None,
        }
    }

    /// The valid `--unit` spellings, for error messages.
    pub const VALID_SPELLINGS: &'static str = "cy/CL, It/s, FLOP/s";

    /// Convert a cycles-per-cacheline figure into this unit.
    ///
    /// `iterations_per_cl` and `flops_per_cl` describe the unit of work;
    /// `clock_hz` converts cycles to seconds.
    pub fn convert(
        &self,
        cy_per_cl: f64,
        iterations_per_cl: f64,
        flops_per_cl: f64,
        clock_hz: f64,
    ) -> f64 {
        match self {
            Unit::CyPerCl => cy_per_cl,
            Unit::ItPerS => iterations_per_cl / (cy_per_cl / clock_hz),
            Unit::FlopPerS => flops_per_cl / (cy_per_cl / clock_hz),
        }
    }

    /// Unit suffix for reports.
    pub fn suffix(&self) -> &'static str {
        match self {
            Unit::CyPerCl => "cy/CL",
            Unit::ItPerS => "It/s",
            Unit::FlopPerS => "FLOP/s",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_parsing() {
        assert_eq!(Unit::parse("cy/CL"), Some(Unit::CyPerCl));
        assert_eq!(Unit::parse("It/s"), Some(Unit::ItPerS));
        assert_eq!(Unit::parse("FLOP/s"), Some(Unit::FlopPerS));
        assert_eq!(Unit::parse("bogus"), None);
    }

    #[test]
    fn unit_parsing_is_case_insensitive() {
        assert_eq!(Unit::parse("CY/CL"), Some(Unit::CyPerCl));
        assert_eq!(Unit::parse("Cy/Cl"), Some(Unit::CyPerCl));
        assert_eq!(Unit::parse("IT/S"), Some(Unit::ItPerS));
        assert_eq!(Unit::parse("flop/S"), Some(Unit::FlopPerS));
        assert_eq!(Unit::parse("FLOPS"), Some(Unit::FlopPerS));
        // every canonical suffix parses back to its own unit
        for u in [Unit::CyPerCl, Unit::ItPerS, Unit::FlopPerS] {
            assert_eq!(Unit::parse(u.suffix()), Some(u));
        }
    }

    #[test]
    fn unit_conversion_roundtrip() {
        // 36.7 cy/CL on a 2.7 GHz machine with 8 it/CL and 32 flop/CL
        let cy = 36.7;
        let its = Unit::ItPerS.convert(cy, 8.0, 32.0, 2.7e9);
        assert!((its - 8.0 * 2.7e9 / 36.7).abs() < 1.0);
        let flops = Unit::FlopPerS.convert(cy, 8.0, 32.0, 2.7e9);
        assert!((flops / its - 4.0).abs() < 1e-9); // 4 flops per iteration
        assert_eq!(Unit::CyPerCl.convert(cy, 8.0, 32.0, 2.7e9), cy);
    }
}
