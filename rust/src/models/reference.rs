//! The paper's published numbers (Table 5) and the benchmark kernel
//! corpus, embedded so every bench/example can print paper-vs-measured
//! deltas.

/// Kernel sources shipped in `kernels/` (paper Listings 3, 6, 7, 8, 9).
pub const KERNEL_2D5PT: &str = include_str!("../../../kernels/2d-5pt.c");
/// UXX stencil (Listing 6).
pub const KERNEL_UXX: &str = include_str!("../../../kernels/uxx.c");
/// Long-range stencil (Listing 7).
pub const KERNEL_LONG_RANGE: &str = include_str!("../../../kernels/long-range.c");
/// Kahan dot product (Listing 8).
pub const KERNEL_KAHAN: &str = include_str!("../../../kernels/kahan-ddot.c");
/// Schönauer triad (Listing 9).
pub const KERNEL_TRIAD: &str = include_str!("../../../kernels/triad.c");
/// 3D 7-point stencil — not part of Table 5 (no published row), but the
/// standard large-working-set kernel for testbed benchmarks.
pub const KERNEL_3D7PT: &str = include_str!("../../../kernels/3d-7pt.c");

/// One Table 5 row as published.
#[derive(Debug, Clone)]
pub struct Table5Row {
    /// Kernel tag ("2D-5pt", "UXX", "long-range", "Kahan-dot", "triad").
    pub kernel: &'static str,
    /// Architecture tag ("SNB"/"HSW").
    pub arch: &'static str,
    /// Problem-size constants as (name, value).
    pub constants: &'static [(&'static str, i64)],
    /// Paper's Kerncraft ECM components {T_OL ‖ T_nOL | L1L2 | L2L3 | L3Mem}.
    pub ecm_model: [f64; 5],
    /// Paper's ECM in-memory prediction (cy/CL).
    pub ecm_mem: f64,
    /// Paper's Roofline in-memory prediction (cy/CL).
    pub roofline: f64,
    /// Paper's Benchmark-mode measurement (cy/CL).
    pub bench: f64,
    /// Reference ECM components from earlier publications, when available.
    pub reference_ecm: Option<[f64; 5]>,
}

/// The complete published Table 5.
pub const TABLE5: &[Table5Row] = &[
    Table5Row {
        kernel: "2D-5pt",
        arch: "SNB",
        constants: &[("N", 6000), ("M", 6000)],
        ecm_model: [9.5, 8.0, 10.0, 6.0, 12.7],
        ecm_mem: 36.7,
        roofline: 29.8,
        bench: 36.4,
        reference_ecm: Some([6.0, 8.0, 10.0, 10.0, 13.0]),
    },
    Table5Row {
        kernel: "2D-5pt",
        arch: "HSW",
        constants: &[("N", 6000), ("M", 6000)],
        ecm_model: [9.4, 8.0, 5.0, 6.0, 16.7],
        ecm_mem: 35.7,
        roofline: 26.6,
        bench: 30.0,
        reference_ecm: None,
    },
    Table5Row {
        kernel: "UXX",
        arch: "SNB",
        constants: &[("N", 150), ("M", 150)],
        ecm_model: [84.0, 32.5, 20.0, 20.0, 26.3],
        ecm_mem: 98.8,
        roofline: 84.0,
        bench: 112.5,
        reference_ecm: Some([84.0, 38.0, 20.0, 20.0, 26.0]),
    },
    Table5Row {
        kernel: "UXX",
        arch: "HSW",
        constants: &[("N", 150), ("M", 150)],
        ecm_model: [56.0, 27.5, 10.0, 20.0, 31.6],
        ecm_mem: 89.1,
        roofline: 61.7,
        bench: 86.9,
        reference_ecm: None,
    },
    Table5Row {
        kernel: "long-range",
        arch: "SNB",
        constants: &[("N", 100), ("M", 100)],
        ecm_model: [57.0, 53.0, 24.0, 24.0, 17.0],
        ecm_mem: 118.0,
        roofline: 65.9,
        bench: 134.2,
        reference_ecm: Some([68.0, 64.0, 24.0, 24.0, 17.0]),
    },
    Table5Row {
        kernel: "long-range",
        arch: "HSW",
        constants: &[("N", 100), ("M", 100)],
        ecm_model: [57.0, 47.5, 12.0, 24.0, 22.3],
        ecm_mem: 105.8,
        roofline: 63.6,
        bench: 104.5,
        reference_ecm: None,
    },
    Table5Row {
        kernel: "Kahan-dot",
        arch: "SNB",
        constants: &[("N", 20_000_000)],
        ecm_model: [96.0, 8.0, 4.0, 4.0, 7.8],
        ecm_mem: 96.0,
        roofline: 96.0,
        bench: 101.1,
        reference_ecm: Some([32.0, 8.0, 4.0, 4.0, 7.9]),
    },
    Table5Row {
        kernel: "Kahan-dot",
        arch: "HSW",
        constants: &[("N", 20_000_000)],
        ecm_model: [96.0, 8.0, 2.0, 4.0, 9.1],
        ecm_mem: 96.0,
        roofline: 96.0,
        bench: 98.0,
        reference_ecm: None,
    },
    Table5Row {
        kernel: "triad",
        arch: "SNB",
        constants: &[("N", 20_000_000)],
        ecm_model: [4.0, 6.0, 10.0, 10.0, 21.9],
        ecm_mem: 47.9,
        roofline: 54.3,
        bench: 58.8,
        reference_ecm: Some([4.0, 6.0, 10.0, 10.0, 24.0]),
    },
    Table5Row {
        kernel: "triad",
        arch: "HSW",
        constants: &[("N", 20_000_000)],
        ecm_model: [4.0, 3.0, 5.0, 10.0, 26.3],
        ecm_mem: 44.3,
        roofline: 46.4,
        bench: 48.3,
        reference_ecm: None,
    },
];

/// Source text of a kernel by its Table 5 tag.
pub fn kernel_source(tag: &str) -> Option<&'static str> {
    Some(match tag {
        "2D-5pt" => KERNEL_2D5PT,
        "UXX" => KERNEL_UXX,
        "long-range" => KERNEL_LONG_RANGE,
        "Kahan-dot" => KERNEL_KAHAN,
        "triad" => KERNEL_TRIAD,
        // addressable by tag for benches/tests, but absent from
        // `kernel_tags()` because Table 5 has no 3D-7pt row
        "3D-7pt" => KERNEL_3D7PT,
        _ => return None,
    })
}

/// All kernel tags in Table 5 order.
pub fn kernel_tags() -> Vec<&'static str> {
    vec!["2D-5pt", "UXX", "long-range", "Kahan-dot", "triad"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::parse;

    #[test]
    fn all_kernel_sources_parse() {
        for tag in kernel_tags() {
            let src = kernel_source(tag).unwrap();
            parse(src).unwrap_or_else(|e| panic!("{tag} fails to parse: {e}"));
        }
        // outside Table 5 but still addressable by tag
        let src = kernel_source("3D-7pt").unwrap();
        parse(src).unwrap_or_else(|e| panic!("3D-7pt fails to parse: {e}"));
    }

    #[test]
    fn table5_covers_both_architectures() {
        for tag in kernel_tags() {
            for arch in ["SNB", "HSW"] {
                assert!(
                    TABLE5.iter().any(|r| r.kernel == tag && r.arch == arch),
                    "missing {tag}/{arch}"
                );
            }
        }
        assert_eq!(TABLE5.len(), 10);
    }

    #[test]
    fn ecm_mem_consistent_with_components() {
        // sanity: published T_ECM,Mem ≈ max(T_OL, T_nOL + ΣT_data)
        for row in TABLE5 {
            let [ol, nol, a, b, c] = row.ecm_model;
            let serial = nol + a + b + c;
            let expect = ol.max(serial);
            assert!(
                (expect - row.ecm_mem).abs() < 0.35,
                "{}/{}: {} vs {}",
                row.kernel,
                row.arch,
                expect,
                row.ecm_mem
            );
        }
    }
}
