//! Execution-Cache-Memory model construction (paper §2.3, §4.6.2).
//!
//! Shorthand notation (cycles per cache line of work):
//!
//! ```text
//! { T_OL ‖ T_nOL | T_L1L2 | T_L2L3 | T_L3Mem }
//! ```
//!
//! The in-memory runtime prediction is
//! `T_ECM,Mem = max(T_OL, T_nOL + T_L1L2 + T_L2L3 + T_L3Mem)`, and the
//! prediction for a data set residing in level *k* truncates the sum.

use crate::cache::TrafficPrediction;
use crate::incore::PortModel;
use crate::machine::MachineModel;
use anyhow::{bail, Result};

/// One inter-level data transfer contribution.
#[derive(Debug, Clone)]
pub struct EcmContribution {
    /// Link label, e.g. "L1L2", "L3Mem".
    pub link: String,
    /// Cache lines crossing this link per unit of work.
    pub lines: f64,
    /// Cycles per unit of work.
    pub cycles: f64,
    /// Microbenchmark used for the bandwidth (memory link only).
    pub benchmark: Option<String>,
}

/// The assembled ECM model for one kernel × machine.
#[derive(Debug, Clone)]
pub struct EcmModel {
    /// Overlapping in-core time (cy/CL).
    pub t_ol: f64,
    /// Non-overlapping (data-port) in-core time (cy/CL).
    pub t_nol: f64,
    /// Data-transfer contributions, inner link first.
    pub contributions: Vec<EcmContribution>,
    /// Iterations per unit of work.
    pub iterations_per_cl: u64,
    /// Source flops per unit of work.
    pub flops_per_cl: f64,
    /// Clock for unit conversions.
    pub clock_hz: f64,
    /// Saturated memory bandwidth used for T_L3Mem (bytes/s).
    pub mem_bandwidth_bs: f64,
}

impl EcmModel {
    /// Assemble the ECM model from the in-core prediction, the traffic
    /// prediction and the machine description.
    pub fn build(
        incore: &PortModel,
        traffic: &TrafficPrediction,
        machine: &MachineModel,
    ) -> Result<EcmModel> {
        Self::build_data(Some(incore), traffic, machine)
    }

    /// ECMData mode (paper §4.6.2): data contributions only; the in-core
    /// part is zero. Useful when no in-core model is available.
    pub fn build_data_only(
        traffic: &TrafficPrediction,
        machine: &MachineModel,
    ) -> Result<EcmModel> {
        Self::build_data(None, traffic, machine)
    }

    fn build_data(
        incore: Option<&PortModel>,
        traffic: &TrafficPrediction,
        machine: &MachineModel,
    ) -> Result<EcmModel> {
        let cl = machine.cacheline_bytes as f64;
        let mut contributions = Vec::new();
        let n_levels = traffic.levels.len();
        if n_levels == 0 {
            bail!("traffic prediction has no cache levels");
        }
        let mut mem_bw = 0.0;
        for (ix, lt) in traffic.levels.iter().enumerate() {
            let outer = if ix + 1 < n_levels {
                traffic.levels[ix + 1].level.clone()
            } else {
                "Mem".to_string()
            };
            let link = format!("{}{}", lt.level, outer);
            let lines = lt.total_lines();
            let lvl = machine
                .level(&lt.level)
                .ok_or_else(|| anyhow::anyhow!("machine lacks level {}", lt.level))?;
            let (cycles, benchmark) = match lvl.cycles_per_cacheline {
                Some(cpc) => (lines * cpc, None),
                None => {
                    // outermost link: saturated measured bandwidth of the
                    // closest-matching microbenchmark (paper §2.3: "the
                    // only measured input")
                    let bench = machine
                        .benchmarks
                        .closest_kernel(&lt.miss_streams)
                        .ok_or_else(|| anyhow::anyhow!("no benchmark kernels in machine file"))?;
                    let bw = machine
                        .benchmarks
                        .saturated_bandwidth("MEM", &bench.name)
                        .ok_or_else(|| {
                            anyhow::anyhow!("no MEM measurement for {}", bench.name)
                        })?;
                    mem_bw = bw;
                    let cy = lines * cl / bw * machine.clock_hz;
                    (cy, Some(bench.name.clone()))
                }
            };
            contributions.push(EcmContribution { link, lines, cycles, benchmark });
        }
        let (t_ol, t_nol, flops, iters) = match incore {
            Some(pm) => (pm.t_ol, pm.t_nol, pm.flops_per_cl, pm.iterations_per_cl),
            None => (0.0, 0.0, 0.0, traffic.unit_iterations),
        };
        Ok(EcmModel {
            t_ol,
            t_nol,
            contributions,
            iterations_per_cl: iters,
            flops_per_cl: flops,
            clock_hz: machine.clock_hz,
            mem_bandwidth_bs: mem_bw,
        })
    }

    /// Transfer time of the outermost (memory) link.
    pub fn t_l3mem(&self) -> f64 {
        self.contributions.last().map(|c| c.cycles).unwrap_or(0.0)
    }

    /// In-memory prediction: `max(T_OL, T_nOL + ΣT_data)`.
    pub fn t_mem(&self) -> f64 {
        let data: f64 = self.contributions.iter().map(|c| c.cycles).sum();
        self.t_ol.max(self.t_nol + data)
    }

    /// Prediction for a data set residing in cache level `k`
    /// (0 = L1: no transfer contributions at all).
    pub fn t_at(&self, k: usize) -> f64 {
        let data: f64 = self.contributions.iter().take(k).map(|c| c.cycles).sum();
        self.t_ol.max(self.t_nol + data)
    }

    /// All per-level predictions `{ECM_L1 \ ECM_L2 \ ... \ ECM_Mem}`.
    pub fn level_predictions(&self) -> Vec<f64> {
        (0..=self.contributions.len()).map(|k| self.t_at(k)).collect()
    }

    /// Core count at which performance saturates:
    /// `n_s = ⌈T_ECM,Mem / T_L3Mem⌉` (paper §2.3).
    pub fn saturation_cores(&self) -> u32 {
        let t_mem_link = self.t_l3mem();
        if t_mem_link <= 0.0 {
            return u32::MAX; // never saturates (cache-resident data)
        }
        (self.t_mem() / t_mem_link).ceil() as u32
    }

    /// Multicore prediction: cycles per cache line of work for the whole
    /// chip with `n` cores (perfect scaling until the bandwidth limit).
    pub fn t_mem_multicore(&self, n: u32) -> f64 {
        let n = n.max(1) as f64;
        let scaled = self.t_mem() / n;
        scaled.max(self.t_l3mem())
    }

    /// The compact model notation, e.g. `{9 ‖ 8 | 10 | 6 | 12.7} cy/CL`
    /// (format shared with the report renderer via
    /// [`crate::util::ecm_notation_str`]).
    pub fn notation(&self) -> String {
        let cycles: Vec<f64> = self.contributions.iter().map(|c| c.cycles).collect();
        crate::util::ecm_notation_str(self.t_ol, self.t_nol, &cycles)
    }

    /// The per-level prediction notation, e.g. `{9 \ 18 \ 24 \ 36.7} cy/CL`.
    pub fn prediction_notation(&self) -> String {
        crate::util::ecm_prediction_str(&self.level_predictions())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CachePredictor;
    use crate::incore::CodegenPolicy;
    use crate::kernel::{parse, KernelAnalysis};
    use std::collections::HashMap;

    fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn build(src: &str, c: &[(&str, i64)], machine: &MachineModel) -> EcmModel {
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(c)).unwrap();
        let pm = PortModel::analyze(&a, machine, &CodegenPolicy::for_machine(machine)).unwrap();
        let t = CachePredictor::new(machine).predict(&a).unwrap();
        EcmModel::build(&pm, &t, machine).unwrap()
    }

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    #[test]
    fn jacobi_snb_full_ecm_matches_table5() {
        // Paper: {9.5 ‖ 8 | 10 | 6 | 12.7}, T_ECM,Mem = 36.7 cy/CL.
        let m = MachineModel::snb();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert_eq!(e.t_nol, 8.0);
        assert!((e.t_ol - 9.0).abs() < 0.6);
        assert_eq!(e.contributions[0].cycles, 10.0, "T_L1L2");
        assert_eq!(e.contributions[1].cycles, 6.0, "T_L2L3");
        assert!((e.contributions[2].cycles - 12.7).abs() < 0.2, "T_L3Mem = {}", e.contributions[2].cycles);
        let t_mem = e.t_mem();
        assert!((t_mem - 36.7).abs() < 0.8, "T_ECM,Mem = {t_mem}");
        assert_eq!(e.contributions[2].benchmark.as_deref(), Some("copy"));
    }

    #[test]
    fn jacobi_hsw_full_ecm_matches_table5() {
        // Paper: {9.4 ‖ 8 | 5 | 6 | 16.7}, T_ECM,Mem = 35.7 cy/CL.
        let m = MachineModel::hsw();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert_eq!(e.t_nol, 8.0);
        assert_eq!(e.contributions[0].cycles, 5.0, "T_L1L2 (64 B/cy on HSW)");
        assert_eq!(e.contributions[1].cycles, 6.0, "T_L2L3");
        assert!((e.contributions[2].cycles - 16.7).abs() < 0.2);
        assert!((e.t_mem() - 35.7).abs() < 0.8);
    }

    #[test]
    fn jacobi_saturates_at_3_cores_on_snb() {
        // Paper Listing 5: "saturating at 3 cores".
        let m = MachineModel::snb();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert_eq!(e.saturation_cores(), 3);
    }

    #[test]
    fn multicore_prediction_saturates() {
        let m = MachineModel::snb();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        let t1 = e.t_mem_multicore(1);
        let t3 = e.t_mem_multicore(3);
        let t8 = e.t_mem_multicore(8);
        assert_eq!(t1, e.t_mem());
        assert!(t3 < t1);
        assert_eq!(t8, e.t_l3mem(), "beyond saturation the bandwidth limit rules");
    }

    #[test]
    fn kahan_ecm_is_core_bound() {
        // Paper: ECM prediction equals T_OL = 96 on both machines.
        let src = r#"
            double a[N], b[N], c;
            double sum, prod, t, y;
            for (int i = 0; i < N; ++i) {
                prod = a[i] * b[i]; y = prod - c;
                t = sum + y; c = (t - sum) - y; sum = t;
            }
        "#;
        for m in [MachineModel::snb(), MachineModel::hsw()] {
            let e = build(src, &[("N", 8000000)], &m);
            assert_eq!(e.t_mem(), 96.0, "{}", m.arch);
            assert_eq!(e.contributions[2].benchmark.as_deref(), Some("load"));
        }
    }

    #[test]
    fn triad_ecm_matches_table5() {
        // Paper SNB: {4 ‖ 6 | 10 | 10 | 21.9} → 47.9 cy/CL.
        let m = MachineModel::snb();
        let e = build(
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];",
            &[("N", 8000000)],
            &m,
        );
        assert_eq!(e.contributions[0].cycles, 10.0);
        assert_eq!(e.contributions[1].cycles, 10.0);
        assert!((e.contributions[2].cycles - 21.9).abs() < 0.3);
        assert!((e.t_mem() - 47.9).abs() < 0.5, "T = {}", e.t_mem());
        // Haswell: {4 ‖ 3 | 5 | 10 | 26.3} → 44.3 cy/CL.
        let h = MachineModel::hsw();
        let e = build(
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];",
            &[("N", 8000000)],
            &h,
        );
        assert_eq!(e.contributions[0].cycles, 5.0);
        assert_eq!(e.contributions[1].cycles, 10.0);
        assert!((e.contributions[2].cycles - 26.3).abs() < 0.3);
        assert!((e.t_mem() - 44.3).abs() < 0.5, "T = {}", e.t_mem());
    }

    #[test]
    fn level_predictions_monotonic() {
        let m = MachineModel::snb();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        let preds = e.level_predictions();
        assert_eq!(preds.len(), 4); // L1, L2, L3, Mem
        for w in preds.windows(2) {
            assert!(w[1] >= w[0], "{preds:?}");
        }
        assert_eq!(preds[3], e.t_mem());
    }

    #[test]
    fn ecm_data_only_mode() {
        let m = MachineModel::snb();
        let p = parse(JACOBI).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 6000), ("M", 6000)])).unwrap();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        let e = EcmModel::build_data_only(&t, &m).unwrap();
        assert_eq!(e.t_ol, 0.0);
        assert_eq!(e.t_nol, 0.0);
        assert!((e.t_mem() - 28.7).abs() < 0.5, "data-only sum: {}", e.t_mem());
    }

    #[test]
    fn notation_renders() {
        let m = MachineModel::snb();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        let n = e.notation();
        assert!(n.starts_with('{'), "{n}");
        assert!(n.contains('\u{2016}'), "{n}");
        assert!(n.contains("| 10 | 6 |"), "{n}");
        let p = e.prediction_notation();
        assert!(p.contains('\\'), "{p}");
    }

    #[test]
    fn ecm_mem_ge_any_single_contribution() {
        // invariant: the serialized sum can never undercut a component
        let m = MachineModel::snb();
        let e = build(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        for c in &e.contributions {
            assert!(e.t_mem() >= c.cycles);
        }
        assert!(e.t_mem() >= e.t_ol);
        assert!(e.t_mem() >= e.t_nol);
    }
}
