//! Multicore scaling model (paper §2.3): perfect scalability until the
//! memory-bandwidth bottleneck, then a flat bandwidth-limited plateau at
//! which the ECM prediction coincides with the bandwidth Roofline.

use super::ecm::EcmModel;
use crate::machine::MachineModel;

/// Chip-level scaling prediction derived from a single-core ECM model.
#[derive(Debug, Clone)]
pub struct ScalingModel {
    /// Single-core in-memory time (cy/CL).
    pub t_single: f64,
    /// Memory-link time (cy/CL) — the plateau.
    pub t_mem_link: f64,
    /// Saturation core count n_s.
    pub saturation: u32,
    /// Cores available in one memory domain.
    pub domain_cores: u32,
    /// Iterations per unit of work (for unit conversion).
    pub iterations_per_cl: u64,
    pub flops_per_cl: f64,
    pub clock_hz: f64,
}

impl ScalingModel {
    /// Build from an assembled ECM model.
    pub fn build(ecm: &EcmModel, machine: &MachineModel) -> ScalingModel {
        ScalingModel {
            t_single: ecm.t_mem(),
            t_mem_link: ecm.t_l3mem(),
            saturation: ecm.saturation_cores(),
            domain_cores: machine.cores_per_numa_domain(),
            iterations_per_cl: ecm.iterations_per_cl,
            flops_per_cl: ecm.flops_per_cl,
            clock_hz: ecm.clock_hz,
        }
    }

    /// Chip throughput with `n` cores, in units of work (cache lines of
    /// work) per cycle.
    pub fn throughput(&self, n: u32) -> f64 {
        let n = n.max(1) as f64;
        if self.t_mem_link <= 0.0 {
            return n / self.t_single; // cache-resident: scales forever
        }
        (n / self.t_single).min(1.0 / self.t_mem_link)
    }

    /// Performance in flop/s with `n` cores.
    pub fn flops(&self, n: u32) -> f64 {
        self.throughput(n) * self.flops_per_cl * self.clock_hz
    }

    /// Speedup over one core.
    pub fn speedup(&self, n: u32) -> f64 {
        self.throughput(n) / self.throughput(1)
    }

    /// The scaling curve up to the domain size: (cores, work/cy).
    pub fn curve(&self) -> Vec<(u32, f64)> {
        (1..=self.domain_cores).map(|n| (n, self.throughput(n))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CachePredictor;
    use crate::incore::{CodegenPolicy, PortModel};
    use crate::kernel::{parse, KernelAnalysis};
    use std::collections::HashMap;

    fn jacobi_scaling(machine: &MachineModel) -> ScalingModel {
        let src = r#"
            double a[M][N], b[M][N], s;
            for (int j = 1; j < M - 1; j++)
                for (int i = 1; i < N - 1; i++)
                    b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
        "#;
        let p = parse(src).unwrap();
        let c: HashMap<String, i64> =
            [("N".to_string(), 6000i64), ("M".to_string(), 6000i64)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &c).unwrap();
        let pm = PortModel::analyze(&a, machine, &CodegenPolicy::for_machine(machine)).unwrap();
        let t = CachePredictor::new(machine).predict(&a).unwrap();
        let ecm = EcmModel::build(&pm, &t, machine).unwrap();
        ScalingModel::build(&ecm, machine)
    }

    #[test]
    fn jacobi_snb_saturates_at_three_cores() {
        let m = MachineModel::snb();
        let s = jacobi_scaling(&m);
        assert_eq!(s.saturation, 3);
        assert_eq!(s.domain_cores, 8);
        // speedup at the plateau: T_single / T_link
        let plateau = s.speedup(8);
        assert!((plateau - s.t_single / s.t_mem_link).abs() < 1e-9);
        // 2 cores still scale perfectly
        assert!((s.speedup(2) - 2.0).abs() < 1e-9);
        // 4 cores are already clamped
        assert!(s.speedup(4) < 4.0);
    }

    #[test]
    fn curve_is_monotonic_nondecreasing() {
        let m = MachineModel::snb();
        let s = jacobi_scaling(&m);
        let curve = s.curve();
        assert_eq!(curve.len(), 8);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn saturated_ecm_equals_bandwidth_roofline() {
        // Paper §2.3: at saturation the ECM prediction coincides with the
        // bandwidth-based Roofline (the plateau is 1/T_L3Mem).
        let m = MachineModel::snb();
        let s = jacobi_scaling(&m);
        let at_sat = s.throughput(s.saturation);
        assert!((at_sat - 1.0 / s.t_mem_link).abs() / at_sat < 0.05);
    }

    #[test]
    fn flops_scale_with_throughput() {
        let m = MachineModel::hsw();
        let s = jacobi_scaling(&m);
        assert!(s.flops(2) > s.flops(1));
        let f7 = s.flops(7);
        let f6 = s.flops(6);
        assert!((f7 - f6).abs() / f7 < 0.2, "plateau reached");
    }
}
