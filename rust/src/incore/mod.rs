//! In-core execution model — the IACA substitute (paper §2.1/§4.4).
//!
//! IACA is proprietary and Intel-only; per the reproduction contract we
//! replace it with an explicit model that computes the same quantities
//! from the same ingredients:
//!
//! 1. **Codegen** ([`CodegenPolicy`]): the kernel statements are lowered
//!    to an abstract µop stream the way the paper's icc 15 `-xAVX` build
//!    would — AVX vectorization (disabled for unbreakable loop-carried
//!    recurrences, cf. Kahan §5.2.1), per-array load widths (arrays with
//!    any 32-byte-misaligned access get half-wide 16 B loads, exactly the
//!    behaviour the paper observes in §5.1.1), optional FMA contraction.
//! 2. **Port scheduling**: µops are distributed over the machine file's
//!    port table; the throughput bound is the exact fractional-scheduling
//!    lower bound max_S (Σ µops with port-set ⊆ S)/|S| over port subsets.
//! 3. **Dependency DAG** ([`dag::DepDag`], DESIGN.md §4): the statements
//!    are lowered to instruction nodes with def-use edges; the
//!    latency-weighted longest path is the critical path (CP) of one
//!    iteration, and cycles through the back-edge to the next iteration
//!    are the loop-carried dependency (LCD) chains, whose maximum
//!    unbreakable cycle mean bounds the overlapping time — reproducing
//!    the 96 cy/CL of the Kahan dot product.
//! 4. **ISA abstraction** ([`isa::IsaSpec`]): instruction selection,
//!    latencies, and port maps resolve from the machine YAML's `isa:` /
//!    `instructions:` blocks, so x86 (SNB/HSW) and AArch64 (A64FX)
//!    machines run through the same model.
//!
//! Outputs are the ECM inputs T_OL and T_nOL in cycles per cache line of
//! work, plus TP/CP/LCD diagnostics mirroring OSACA's report surface.

pub mod dag;
pub mod isa;

use crate::kernel::KernelAnalysis;
use crate::machine::{MachineModel, UopClass};
use anyhow::{bail, Result};
use isa::{IsaFamily, IsaSpec};

/// Compiler-behaviour model used when lowering the kernel to µops.
#[derive(Debug, Clone)]
pub struct CodegenPolicy {
    /// Vectorize with this many elements per SIMD lane set (1 = scalar).
    /// Automatically reduced to 1 when an unbreakable recurrence exists.
    pub vector_elems: u32,
    /// Contract mul+add pairs to FMA.
    pub fma_contract: bool,
    /// Loads from arrays with any misaligned access are split in half
    /// (icc `-xAVX` behaviour on Sandy Bridge).
    pub split_unaligned_loads: bool,
    /// Break single-statement reductions by modulo variable expansion
    /// (icc default `-fp-model fast`); multi-statement recurrences like
    /// Kahan are never broken.
    pub break_reductions: bool,
}

impl CodegenPolicy {
    /// The policy matching the paper's build (icc 15, `-xAVX`, one binary
    /// for both machines).
    pub fn for_machine(machine: &MachineModel) -> Self {
        CodegenPolicy {
            vector_elems: (machine.isa.vector_bytes / 8).max(1) as u32,
            fma_contract: machine.isa.fma,
            // the modeled compiler splits misaligned-stream loads when its
            // preferred load width is below the SIMD width (icc -xAVX does
            // this; the paper runs ONE such binary on both machines)
            split_unaligned_loads: machine.isa.preferred_load_bytes < machine.isa.vector_bytes,
            break_reductions: true,
        }
    }

    /// Fully scalar policy (no SIMD, no FMA) — the naive-codegen baseline.
    pub fn scalar() -> Self {
        CodegenPolicy {
            vector_elems: 1,
            fma_contract: false,
            split_unaligned_loads: false,
            break_reductions: false,
        }
    }
}

/// Per-port pressure in cycles per cache line of work.
#[derive(Debug, Clone, PartialEq)]
pub struct PortPressure {
    pub port: String,
    pub cycles: f64,
}

/// µop counts per cache line of work (diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct UopCounts {
    pub load: f64,
    pub store: f64,
    pub agu: f64,
    pub add: f64,
    pub mul: f64,
    pub fma: f64,
    pub div: f64,
    pub misc: f64,
}

/// One loop-carried dependency chain, resolved to machine instructions
/// (the per-chain breakdown of OSACA's LCD report).
#[derive(Debug, Clone, PartialEq)]
pub struct ChainData {
    /// Carried scalars on the cycle, joined with `->` (e.g. `c->sum`).
    pub name: String,
    /// Cycle-mean latency per scalar iteration.
    pub latency_per_it: f64,
    /// Chain cost per cache line of work (cycle mean × iterations/CL).
    pub cy_per_unit: f64,
    /// True when modulo variable expansion breaks this chain.
    pub broken: bool,
    /// Resolved mnemonics along the maximum-latency cycle path.
    pub instructions: Vec<String>,
}

/// The in-core prediction (all numbers in cycles per cache line of work).
#[derive(Debug, Clone)]
pub struct PortModel {
    /// ISA family the instruction selection was resolved for.
    pub isa: IsaFamily,
    /// Overlapping time: max pressure on overlapping ports, or the
    /// loop-carried dependency bound if that is larger.
    pub t_ol: f64,
    /// Non-overlapping time: pressure on the data ports ("2D"/"3D").
    pub t_nol: f64,
    /// Pure throughput bound (max over all ports) — IACA "TP".
    pub tp: f64,
    /// Critical path of the dependency DAG per cache line of work —
    /// OSACA "CP": the longest latency-weighted def-use path of one
    /// iteration, scaled to cy/CL.
    pub cp_cy: f64,
    /// Loop-carried dependency bound per cache line (0 when none) —
    /// OSACA "LCD": the maximum unbreakable cycle mean × iterations/CL.
    pub lcd_cy: f64,
    /// Whether the code was vectorized.
    pub vectorized: bool,
    /// Elements per SIMD operation used.
    pub vector_elems: u32,
    /// Port pressure table.
    pub pressure: Vec<PortPressure>,
    /// Loop-carried dependency chains, unbroken-first then by
    /// descending latency (deterministic).
    pub chains: Vec<ChainData>,
    /// Name of the dominant (unbroken, highest-latency) chain, if any.
    pub dominant_chain: Option<String>,
    /// µop counts per cache line.
    pub uops: UopCounts,
    /// Source-level flops per cache line of work.
    pub flops_per_cl: f64,
    /// Inner iterations per cache line of work.
    pub iterations_per_cl: u64,
}

impl PortModel {
    /// Analyze a kernel on a machine under a codegen policy.
    pub fn analyze(
        analysis: &KernelAnalysis,
        machine: &MachineModel,
        policy: &CodegenPolicy,
    ) -> Result<PortModel> {
        if analysis.loops.is_empty() {
            bail!("kernel has no loops");
        }
        let elem = analysis.element.size();
        let iterations_per_cl = analysis.unit_of_work(machine.cacheline_bytes);

        // --- dependency DAG: CP + LCD chains (DESIGN.md §4) ---
        // Latencies are width-independent in the resolved spec, so the
        // DAG built with the probe spec stays valid after the
        // vectorization decision; only mnemonics are re-resolved below.
        let probe = IsaSpec::resolve(machine, true);
        let dep = dag::DepDag::build(analysis, &probe);
        let raw_chains = dep.chains(policy.break_reductions);
        let unbreakable = raw_chains
            .iter()
            .filter(|c| !c.broken)
            .map(|c| c.latency_per_it)
            .fold(0.0f64, f64::max);
        let vector_elems = if unbreakable > 0.0 { 1 } else { policy.vector_elems.max(1) };
        let vectorized = vector_elems > 1;
        let isa_spec = IsaSpec::resolve(machine, vectorized);
        let lcd_cy = unbreakable * iterations_per_cl as f64;
        let (cp_per_it, _) = dep.critical_path();
        let cp_cy = cp_per_it * iterations_per_cl as f64 / vector_elems as f64;
        let chains: Vec<ChainData> = raw_chains
            .iter()
            .map(|c| ChainData {
                name: c.vars.join("->"),
                latency_per_it: c.latency_per_it,
                cy_per_unit: c.latency_per_it * iterations_per_cl as f64 / vector_elems as f64,
                broken: c.broken,
                instructions: c
                    .path
                    .iter()
                    .filter_map(|&id| match &dep.nodes()[id].kind {
                        dag::NodeKind::Load => Some(isa_spec.mnemonic(UopClass::Load).to_string()),
                        dag::NodeKind::Op(class) => Some(isa_spec.mnemonic(*class).to_string()),
                        dag::NodeKind::Phi(_) | dag::NodeKind::Store => None,
                    })
                    .collect(),
            })
            .collect();
        let dominant_chain = chains.iter().find(|c| !c.broken).map(|c| c.name.clone());

        // --- load/store µop accounting ---
        // Arrays with any 32 B-misaligned read access get half-wide loads
        // when the policy splits unaligned loads.
        let vec_bytes = (vector_elems as u64 * elem).max(elem);
        let mut misaligned = vec![false; analysis.arrays.len()];
        if policy.split_unaligned_loads && vectorized {
            for acc in &analysis.reads {
                if (acc.offset * elem as i64).rem_euclid(machine.isa.vector_bytes as i64) != 0 {
                    misaligned[acc.array] = true;
                }
            }
        }
        let mut load_uops = 0f64;
        let mut load_instr = 0f64;
        for acc in &analysis.reads {
            // each access streams one cache line of each array per CL of
            // work (scalar offsets inside one line are register-reused)
            let bytes = machine.cacheline_bytes as f64;
            let instr_bytes = if !vectorized {
                elem
            } else if misaligned[acc.array] {
                (vec_bytes / 2).max(elem)
            } else {
                vec_bytes
            };
            let n_instr = bytes / instr_bytes as f64;
            let uops_per_instr = (instr_bytes as f64 / machine.isa.load_uop_bytes as f64).max(1.0);
            load_instr += n_instr;
            load_uops += n_instr * uops_per_instr;
        }
        let mut store_uops = 0f64;
        let mut store_instr = 0f64;
        for _acc in &analysis.writes {
            let bytes = machine.cacheline_bytes as f64;
            let instr_bytes = if vectorized { vec_bytes } else { elem };
            let n_instr = bytes / instr_bytes as f64;
            let uops_per_instr =
                (instr_bytes as f64 / machine.isa.store_uop_bytes as f64).max(1.0);
            store_instr += n_instr;
            store_uops += n_instr * uops_per_instr;
        }
        let agu_uops = load_instr + store_instr;

        // --- arithmetic µop accounting ---
        let f = analysis.flops;
        let (mut adds, mut muls) = (f.adds as f64, f.muls as f64);
        let mut fmas = 0f64;
        if policy.fma_contract && vectorized {
            let fused = adds.min(muls);
            fmas = fused;
            adds -= fused;
            muls -= fused;
        }
        let divs = f.divs as f64;
        let simd_ops_per_cl = |per_iter: f64| -> f64 {
            per_iter * iterations_per_cl as f64 / vector_elems as f64
        };
        let add_uops = simd_ops_per_cl(adds);
        let mul_uops = simd_ops_per_cl(muls);
        let fma_uops = simd_ops_per_cl(fmas);
        let div_uops = simd_ops_per_cl(divs);
        // loop overhead: compare+branch+index increment per asm iteration
        let misc_uops = 2.0 * iterations_per_cl as f64 / vector_elems as f64;

        let uops = UopCounts {
            load: load_uops,
            store: store_uops,
            agu: agu_uops,
            add: add_uops,
            mul: mul_uops,
            fma: fma_uops,
            div: div_uops,
            misc: misc_uops,
        };

        // --- port scheduling ---
        // class → (uop count, cycles per uop)
        let div_cost = machine.div_cycles(vector_elems);
        let class_load: Vec<(UopClass, f64)> = vec![
            (UopClass::Load, load_uops),
            (UopClass::Store, store_uops),
            (UopClass::Agu, agu_uops),
            (UopClass::Add, add_uops),
            (UopClass::Mul, mul_uops),
            (UopClass::Fma, fma_uops),
            (UopClass::Div, div_uops * div_cost),
            (UopClass::Misc, misc_uops),
        ];
        let sched = schedule_ports(machine, &isa_spec, &class_load)?;
        let t_nol = sched.max_over(machine, &machine.non_overlapping_ports);
        let t_ol_ports = sched.max_over(machine, &machine.overlapping_ports);
        let t_ol = t_ol_ports.max(lcd_cy);
        let tp = sched.global_max;
        let pressure = sched.pressure;

        Ok(PortModel {
            isa: isa_spec.family,
            t_ol,
            t_nol,
            tp,
            cp_cy,
            lcd_cy,
            vectorized,
            vector_elems,
            pressure,
            chains,
            dominant_chain,
            uops,
            flops_per_cl: f.total() as f64 * iterations_per_cl as f64,
            iterations_per_cl,
        })
    }
}

/// Result of scheduling µop classes onto ports.
struct Schedule {
    /// Per-port pressure under an optimal (min-max) fractional schedule.
    pressure: Vec<PortPressure>,
    /// (port-mask, load) pairs, kept for subset queries.
    masks: Vec<(u32, f64)>,
    /// Exact optimal makespan over all ports.
    global_max: f64,
}

impl Schedule {
    /// Exact optimal max pressure over the given port subset: the
    /// fractional-scheduling bound max_S (sum of classes with ports in S)/|S|,
    /// restricted to subsets of `names`.
    fn max_over(&self, machine: &MachineModel, names: &[String]) -> f64 {
        let mut allowed = 0u32;
        for (i, p) in machine.ports.iter().enumerate() {
            if names.contains(&p.name) {
                allowed |= 1 << i;
            }
        }
        subset_bound_masked(&self.masks, allowed)
    }
}

/// Distribute µop classes over ports with an optimal min-max fractional
/// schedule. The achievable makespan equals the lower bound
/// max_S (sum of loads of classes with port-set in S) / |S| over subsets.
/// A class with an explicit `instructions:` port override in the machine
/// file is pinned to exactly those ports; every other class goes by the
/// port table's accept lists.
fn schedule_ports(
    machine: &MachineModel,
    isa: &IsaSpec,
    class_load: &[(UopClass, f64)],
) -> Result<Schedule> {
    let n = machine.ports.len();
    if n == 0 {
        bail!("machine has no ports");
    }
    if n > 20 {
        bail!("port table too large for subset scheduling");
    }
    // port mask per class
    let mut masks: Vec<(u32, f64)> = Vec::new();
    for &(class, load) in class_load {
        if load <= 0.0 {
            continue;
        }
        let mut mask = 0u32;
        let overridden = isa.port_override(class);
        if overridden.is_empty() {
            for (i, p) in machine.ports.iter().enumerate() {
                if p.accepts.contains(&class) {
                    mask |= 1 << i;
                }
            }
        } else {
            for name in overridden {
                match machine.ports.iter().position(|p| &p.name == name) {
                    Some(i) => mask |= 1 << i,
                    None => bail!(
                        "instructions override for {:?} names unknown port {} on {}",
                        class,
                        name,
                        machine.arch
                    ),
                }
            }
        }
        if mask == 0 {
            bail!("no port accepts {:?} uops on {}", class, machine.arch);
        }
        masks.push((mask, load));
    }
    let global_max = subset_bound_masked(&masks, (1u32 << n) - 1);

    // Per-port pressure for reporting: water-fill classes in order of
    // ascending port-set size (most-constrained first), topping up the
    // least-loaded legal ports. Exact for laminar port-set families
    // (ours are: ADD {1} inside FMA/MUL {0,1}; everything else disjoint).
    let mut cycles = vec![0f64; n];
    let mut order: Vec<&(u32, f64)> = masks.iter().collect();
    order.sort_by_key(|(m, _)| m.count_ones());
    for &&(mask, load) in &order {
        let ports: Vec<usize> = (0..n).filter(|i| mask & (1 << i) != 0).collect();
        let mut remaining = load;
        while remaining > 1e-12 {
            let min_level = ports.iter().map(|&i| cycles[i]).fold(f64::INFINITY, f64::min);
            let at_min: Vec<usize> =
                ports.iter().copied().filter(|&i| cycles[i] <= min_level + 1e-12).collect();
            let next_level = ports
                .iter()
                .map(|&i| cycles[i])
                .filter(|&c| c > min_level + 1e-12)
                .fold(f64::INFINITY, f64::min);
            let room = if next_level.is_finite() {
                (next_level - min_level) * at_min.len() as f64
            } else {
                f64::INFINITY
            };
            let fill = remaining.min(room);
            let per = fill / at_min.len() as f64;
            for &i in &at_min {
                cycles[i] += per;
            }
            remaining -= fill;
        }
    }
    let pressure = machine
        .ports
        .iter()
        .zip(cycles)
        .map(|(p, c)| PortPressure { port: p.name.clone(), cycles: c })
        .collect();
    Ok(Schedule { pressure, masks, global_max })
}

/// Fractional scheduling bound restricted to subsets of `allowed`.
fn subset_bound_masked(masks: &[(u32, f64)], allowed: u32) -> f64 {
    let mut best = 0f64;
    let mut subset = allowed;
    loop {
        if subset != 0 {
            let mut load = 0f64;
            for &(mask, l) in masks {
                if mask & !subset == 0 {
                    load += l;
                }
            }
            best = best.max(load / subset.count_ones() as f64);
        }
        if subset == 0 {
            break;
        }
        subset = (subset - 1) & allowed;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{parse, KernelAnalysis};
    use std::collections::HashMap as Map;

    fn consts(pairs: &[(&str, i64)]) -> Map<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn analyze(src: &str, c: &[(&str, i64)], machine: &MachineModel) -> PortModel {
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(c)).unwrap();
        PortModel::analyze(&a, machine, &CodegenPolicy::for_machine(machine)).unwrap()
    }

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    const KAHAN: &str = r#"
        double a[N], b[N], c;
        double sum, prod, t, y;
        for (int i = 0; i < N; ++i) {
            prod = a[i] * b[i];
            y = prod - c;
            t = sum + y;
            c = (t - sum) - y;
            sum = t;
        }
    "#;

    const TRIAD: &str =
        "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";

    #[test]
    fn jacobi_snb_tol_tnol_match_paper() {
        // Paper Table 5: SNB {9.5 ‖ 8 | ...} — we model 9/8 (the 0.5
        // difference stems from odd spill µops IACA sees; documented).
        let m = MachineModel::snb();
        let pm = analyze(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert!(pm.vectorized);
        assert_eq!(pm.t_nol, 8.0, "{:?}", pm.pressure);
        assert!((pm.t_ol - 9.0).abs() < 0.6, "T_OL = {}", pm.t_ol);
    }

    #[test]
    fn jacobi_hsw_tol_tnol_match_paper() {
        // Paper: HSW {9.4 ‖ 8 | ...}
        let m = MachineModel::hsw();
        let pm = analyze(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert_eq!(pm.t_nol, 8.0, "{:?}", pm.pressure);
        assert!((pm.t_ol - 9.0).abs() < 0.6, "T_OL = {}", pm.t_ol);
    }

    #[test]
    fn kahan_recurrence_dominates() {
        // Paper: T_OL = 96 cy/CL on both architectures — the 12 cy
        // loop-carried chain (4 sequential 3 cy adds) × 8 iterations.
        for m in [MachineModel::snb(), MachineModel::hsw()] {
            let pm = analyze(KAHAN, &[("N", 1000000)], &m);
            assert!(!pm.vectorized, "loop-carried dependency forbids SIMD");
            assert_eq!(pm.lcd_cy, 96.0, "{}", m.arch);
            assert_eq!(pm.t_ol, 96.0, "{}", m.arch);
            assert_eq!(pm.t_nol, 8.0, "{} {:?}", m.arch, pm.pressure);
            // the dominant chain is the 4-add c → c recurrence; the full
            // DAG critical path also crosses the load and multiply:
            // 4 + 5 + 4×3 = 21 cy/it → 168 cy/CL
            assert_eq!(pm.dominant_chain.as_deref(), Some("c"), "{}", m.arch);
            assert_eq!(pm.cp_cy, 168.0, "{}", m.arch);
            assert!(pm.cp_cy >= pm.lcd_cy);
            assert!(pm.lcd_cy > pm.tp, "LCD must dominate throughput");
        }
    }

    #[test]
    fn kahan_chain_breakdown_is_deterministic() {
        let m = MachineModel::snb();
        let pm = analyze(KAHAN, &[("N", 1000000)], &m);
        let names: Vec<&str> = pm.chains.iter().map(|c| c.name.as_str()).collect();
        // unbroken chains by descending cycle mean: c (12), c->sum
        // ((6+9)/2 = 7.5), sum (3)
        assert_eq!(names, ["c", "c->sum", "sum"]);
        assert_eq!(pm.chains[0].latency_per_it, 12.0);
        assert_eq!(pm.chains[1].latency_per_it, 7.5);
        assert_eq!(pm.chains[2].latency_per_it, 3.0);
        assert!(pm.chains.iter().all(|c| !c.broken));
        // scalar x86 selection: the c chain is four dependent adds
        assert_eq!(pm.chains[0].instructions, ["addsd"; 4]);
        let pm2 = analyze(KAHAN, &[("N", 1000000)], &m);
        assert_eq!(pm.chains, pm2.chains, "chain ordering must be stable");
    }

    #[test]
    fn triad_snb_matches_paper() {
        // Paper: SNB {4 ‖ 6 | ...}: aligned streams ⇒ full-wide loads.
        let m = MachineModel::snb();
        let pm = analyze(TRIAD, &[("N", 8000000)], &m);
        assert_eq!(pm.t_nol, 6.0, "{:?}", pm.pressure);
        assert_eq!(pm.t_ol, 4.0, "{:?}", pm.pressure);
    }

    #[test]
    fn triad_hsw_matches_paper() {
        // Paper: HSW {4 ‖ 3 | ...}: full-wide loads are single µops.
        let m = MachineModel::hsw();
        let pm = analyze(TRIAD, &[("N", 8000000)], &m);
        assert_eq!(pm.t_nol, 3.0, "{:?}", pm.pressure);
        assert_eq!(pm.t_ol, 4.0, "{:?}", pm.pressure);
    }

    #[test]
    fn dot_product_reduction_is_broken() {
        // s += a[i]*b[i] — icc breaks the reduction by MVE ⇒ vectorized,
        // no recurrence bound (paper §2.1).
        let m = MachineModel::snb();
        let pm = analyze(
            "double a[N], b[N], s;\nfor (int i = 0; i < N; i++) s += a[i] * b[i];",
            &[("N", 1000000)],
            &m,
        );
        assert!(pm.vectorized);
        assert_eq!(pm.lcd_cy, 0.0);
        // the broken reduction still shows up in the chain breakdown
        assert_eq!(pm.chains.len(), 1);
        assert!(pm.chains[0].broken);
        assert_eq!(pm.dominant_chain, None);
    }

    #[test]
    fn scalar_policy_disables_simd() {
        let m = MachineModel::snb();
        let p = parse(TRIAD).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 1000)])).unwrap();
        let pm = PortModel::analyze(&a, &m, &CodegenPolicy::scalar()).unwrap();
        assert!(!pm.vectorized);
        // scalar loads: 3 arrays × 8 elements = 24 µops on 2 ports
        assert_eq!(pm.t_nol, 12.0);
    }

    #[test]
    fn division_occupies_divider() {
        // UXX-like: one divide per iteration ⇒ 2 vector divides per CL at
        // 42 cy each on SNB (Table 5: T_OL = 84).
        let src = r#"
            double u[M][N], d[M][N], dth;
            for (int j = 1; j < M-1; j++)
                for (int i = 1; i < N-1; i++)
                    u[j][i] = u[j][i] + dth / d[j][i];
        "#;
        let m = MachineModel::snb();
        let pm = analyze(src, &[("N", 500), ("M", 500)], &m);
        assert_eq!(pm.t_ol, 84.0, "{:?}", pm.pressure);
        let h = MachineModel::hsw();
        let pmh = analyze(src, &[("N", 500), ("M", 500)], &h);
        assert_eq!(pmh.t_ol, 56.0, "{:?}", pmh.pressure);
    }

    #[test]
    fn tp_at_least_max_of_tol_tnol_parts() {
        let m = MachineModel::snb();
        let pm = analyze(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert!(pm.tp <= pm.t_ol.max(pm.t_nol) + 1e-9);
        assert!(pm.tp >= pm.t_nol - 1e-9);
    }

    #[test]
    fn property_cp_nonnegative_and_tp_positive() {
        let mut rng = crate::util::XorShift64::new(0xBEEF);
        let m = MachineModel::snb();
        for _ in 0..8 {
            let k = rng.next_range(1, 3);
            let src = format!(
                "double a[N], b[N], c[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] * {k}.0 + c[i+{k}];"
            );
            let pm = analyze(&src, &[("N", 100000)], &m);
            assert!(pm.lcd_cy >= 0.0);
            assert!(pm.cp_cy >= pm.lcd_cy);
            assert!(pm.tp > 0.0);
            assert!(pm.t_nol > 0.0);
        }
    }

    #[test]
    fn flops_per_cl() {
        let m = MachineModel::snb();
        let pm = analyze(TRIAD, &[("N", 100000)], &m);
        assert_eq!(pm.flops_per_cl, 16.0); // 2 flops × 8 iterations
    }

    #[test]
    fn report_contains_ports() {
        // exactly one in-core text renderer: the pure report function
        // over the serialized section
        let m = MachineModel::snb();
        let pm = analyze(TRIAD, &[("N", 100000)], &m);
        let r = crate::report::incore_report(&crate::session::IncoreReport::from_model(&pm));
        assert!(r.contains("T_OL"));
        assert!(r.contains("port pressure"));
        assert!(r.contains("CP"));
        assert!(r.contains("LCD"));
    }

    #[test]
    fn a64fx_analyzes_with_sve_selection() {
        // the AArch64 machine runs through the same model with SVE
        // instruction selection and its own latencies (ADD 9 cy)
        let m = MachineModel::builtin("a64fx").expect("a64fx is a builtin");
        let pm = analyze(KAHAN, &[("N", 1000000)], &m);
        assert_eq!(pm.isa, IsaFamily::AArch64);
        assert!(!pm.vectorized);
        // 256 B cache line → 32 iterations; 4 dependent 9 cy adds
        assert_eq!(pm.lcd_cy, 9.0 * 4.0 * 32.0);
        assert_eq!(pm.chains[0].instructions, ["fadd"; 4]);
        let t = analyze(TRIAD, &[("N", 8000000)], &m);
        assert!(t.vectorized, "no recurrence: SVE vectorizes the triad");
    }
}
