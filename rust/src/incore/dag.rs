//! Instruction dependency DAG (DESIGN.md §4) — the OSACA-style
//! critical-path / loop-carried-dependency layer of the in-core engine.
//!
//! The kernel's innermost statements are lowered to instruction nodes
//! (loads, arithmetic ops, stores) connected by register/memory def-use
//! edges. Loop-carried scalars get a φ source node standing for "the
//! value arriving from the previous iteration"; after the statement walk,
//! each carried scalar's final definition is wired back to its φ node as
//! a *back-edge*. The graph is then
//!
//! * acyclic over forward edges (node ids are a topological order by
//!   construction), giving the latency-weighted longest path — the
//!   **critical path** (CP) of one iteration, and
//! * cyclic only through back-edges, whose simple cycles are the
//!   **loop-carried dependency** (LCD) chains; a chain's cost per
//!   iteration is its cycle mean — total path latency divided by the
//!   number of back-edges (iterations) it spans.
//!
//! This mirrors OSACA's `get_cp`/`get_lcd` surface (arXiv:1809.00912) at
//! the granularity of this reproduction's µop classes.

use super::isa::IsaSpec;
use crate::kernel::{AssignOp, BinOp, Expr, KernelAnalysis};
use crate::machine::UopClass;
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// What a DAG node stands for.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// Value of a loop-carried scalar arriving from the previous
    /// iteration (latency 0; target of exactly one back-edge).
    Phi(String),
    /// Array-element load.
    Load,
    /// Array-element store (latency 0 — feeds nothing).
    Store,
    /// Arithmetic operation (`Add` covers subtraction).
    Op(UopClass),
}

/// One instruction node: kind, result latency, and def-use inputs
/// (forward edges; every input id is smaller than the node's own id).
#[derive(Debug, Clone)]
pub struct Node {
    pub kind: NodeKind,
    pub latency: f64,
    pub inputs: Vec<usize>,
}

/// One loop-carried dependency chain: a simple cycle through back-edges.
#[derive(Debug, Clone, PartialEq)]
pub struct Chain {
    /// Carried scalars on the cycle, rooted at the lexicographically
    /// smallest (deterministic identity).
    pub vars: Vec<String>,
    /// Cycle mean: summed node latency around the cycle divided by the
    /// number of back-edges (= iterations the cycle spans).
    pub latency_per_it: f64,
    /// True when modulo variable expansion breaks this chain (a pure
    /// single-op reduction under `break_reductions`).
    pub broken: bool,
    /// Node ids along the maximum-latency path realizing the cycle.
    pub path: Vec<usize>,
}

/// The dependency DAG of one kernel iteration.
#[derive(Debug, Clone)]
pub struct DepDag {
    nodes: Vec<Node>,
    /// φ-source set of every node: which carried scalars it depends on.
    phi_deps: Vec<BTreeSet<String>>,
    /// Carried scalar → its φ node, sorted by name.
    phi: Vec<(String, usize)>,
    /// Back-edges: (final definition node, φ node) per carried scalar.
    back: Vec<(usize, usize)>,
    /// Carried scalars whose recurrence is a breakable pure reduction.
    breakable: BTreeSet<String>,
}

fn op_class(op: BinOp) -> UopClass {
    match op {
        BinOp::Add | BinOp::Sub => UopClass::Add,
        BinOp::Mul => UopClass::Mul,
        BinOp::Div => UopClass::Div,
    }
}

/// `s = s + expr` (or `s = expr + s`) with no other carried deps counts
/// as a simple reduction (same shape the throughput model breaks).
fn is_simple_self_update(rhs: &Expr, name: &str) -> bool {
    match rhs {
        Expr::Binary { op: BinOp::Add | BinOp::Mul, lhs, rhs } => {
            matches!(lhs.as_ref(), Expr::Var(v) if v == name)
                || matches!(rhs.as_ref(), Expr::Var(v) if v == name)
        }
        _ => false,
    }
}

impl DepDag {
    /// Lower the innermost statements to the dependency DAG under the
    /// machine's resolved instruction latencies.
    pub fn build(analysis: &KernelAnalysis, isa: &IsaSpec) -> DepDag {
        let carried: Vec<String> =
            analysis.carried_scalars().into_iter().map(str::to_string).collect();
        let mut dag = DepDag {
            nodes: Vec::new(),
            phi_deps: Vec::new(),
            phi: Vec::new(),
            back: Vec::new(),
            breakable: BTreeSet::new(),
        };
        // scalar name → defining node (φ initially for carried scalars;
        // loop-invariant sources stay absent — they live in registers)
        let mut env: HashMap<String, usize> = HashMap::new();
        for c in &carried {
            let id = dag.add(NodeKind::Phi(c.clone()), 0.0, Vec::new());
            dag.phi.push((c.clone(), id));
            env.insert(c.clone(), id);
        }
        let mut final_def: BTreeMap<String, usize> = BTreeMap::new();

        for st in &analysis.stmts {
            let rhs_node = dag.lower_expr(&st.rhs, &env, isa);
            // compound assignment folds the destination's prior value in
            let value_node = match st.op.bin_op() {
                None => rhs_node,
                Some(op) => {
                    let class = op_class(op);
                    let mut inputs = Vec::new();
                    match &st.lhs {
                        Expr::Var(v) => {
                            if let Some(&n) = env.get(v) {
                                inputs.push(n);
                            }
                        }
                        Expr::Index { .. } => {
                            inputs.push(dag.add(
                                NodeKind::Load,
                                isa.latency(UopClass::Load),
                                Vec::new(),
                            ));
                        }
                        _ => {}
                    }
                    if let Some(r) = rhs_node {
                        inputs.push(r);
                    }
                    Some(dag.add(NodeKind::Op(class), isa.latency(class), inputs))
                }
            };
            match &st.lhs {
                Expr::Var(name) => {
                    match value_node {
                        Some(n) => {
                            env.insert(name.clone(), n);
                        }
                        // constant assignment kills the carried value
                        None => {
                            env.remove(name);
                        }
                    }
                    if carried.contains(name) {
                        if let Some(n) = value_node {
                            final_def.insert(name.clone(), n);
                            let self_only = dag.phi_deps[n].len() == 1
                                && dag.phi_deps[n].contains(name);
                            let simple = matches!(st.op, AssignOp::Add | AssignOp::Mul)
                                || is_simple_self_update(&st.rhs, name);
                            if self_only && simple {
                                dag.breakable.insert(name.clone());
                            } else {
                                dag.breakable.remove(name);
                            }
                        } else {
                            final_def.remove(name);
                            dag.breakable.remove(name);
                        }
                    }
                }
                Expr::Index { .. } => {
                    let inputs = value_node.into_iter().collect();
                    dag.add(NodeKind::Store, isa.latency(UopClass::Store), inputs);
                }
                _ => {}
            }
        }

        // back-edges: final definition of each carried scalar feeds its
        // own φ in the next iteration
        for (c, phi_id) in &dag.phi {
            if let Some(&def) = final_def.get(c) {
                if def != *phi_id {
                    dag.back.push((def, *phi_id));
                }
            }
        }
        dag
    }

    fn add(&mut self, kind: NodeKind, latency: f64, inputs: Vec<usize>) -> usize {
        let id = self.nodes.len();
        let mut deps = BTreeSet::new();
        for &i in &inputs {
            deps.extend(self.phi_deps[i].iter().cloned());
        }
        if let NodeKind::Phi(name) = &kind {
            deps.insert(name.clone());
        }
        self.phi_deps.push(deps);
        self.nodes.push(Node { kind, latency, inputs });
        id
    }

    fn lower_expr(
        &mut self,
        e: &Expr,
        env: &HashMap<String, usize>,
        isa: &IsaSpec,
    ) -> Option<usize> {
        match e {
            Expr::Int(_) | Expr::Float(_) => None,
            Expr::Var(v) => env.get(v).copied(),
            // negation folds into the consuming op (sign flip is free on
            // every modeled ISA)
            Expr::Neg(inner) => self.lower_expr(inner, env, isa),
            Expr::Index { .. } => {
                Some(self.add(NodeKind::Load, isa.latency(UopClass::Load), Vec::new()))
            }
            Expr::Binary { op, lhs, rhs } => {
                let l = self.lower_expr(lhs, env, isa);
                let r = self.lower_expr(rhs, env, isa);
                let class = op_class(*op);
                let inputs: Vec<usize> = l.into_iter().chain(r).collect();
                Some(self.add(NodeKind::Op(class), isa.latency(class), inputs))
            }
        }
    }

    /// All nodes (read-only view for consumers rendering chains).
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Back-edges (from final definition to φ node).
    pub fn back_edges(&self) -> &[(usize, usize)] {
        &self.back
    }

    /// Forward edges are acyclic by construction: every input id is
    /// strictly smaller than its node's id (ids ARE a topological
    /// order). The property tests pin this invariant.
    pub fn is_topologically_ordered(&self) -> bool {
        self.nodes
            .iter()
            .enumerate()
            .all(|(id, n)| n.inputs.iter().all(|&i| i < id))
    }

    /// Largest single-node latency in the graph.
    pub fn max_node_latency(&self) -> f64 {
        self.nodes.iter().map(|n| n.latency).fold(0.0, f64::max)
    }

    /// Latency-weighted longest forward path of one iteration: the
    /// critical path. Returns (total latency, node ids along the path in
    /// execution order).
    pub fn critical_path(&self) -> (f64, Vec<usize>) {
        let n = self.nodes.len();
        let mut dist = vec![0.0f64; n];
        let mut pred: Vec<Option<usize>> = vec![None; n];
        for id in 0..n {
            let mut best = 0.0f64;
            let mut from = None;
            for &i in &self.nodes[id].inputs {
                if dist[i] > best {
                    best = dist[i];
                    from = Some(i);
                }
            }
            dist[id] = best + self.nodes[id].latency;
            pred[id] = from;
        }
        let Some(end) = (0..n).max_by(|&a, &b| dist[a].total_cmp(&dist[b])) else {
            return (0.0, Vec::new());
        };
        let mut path = Vec::new();
        let mut cur = Some(end);
        while let Some(id) = cur {
            path.push(id);
            cur = pred[id];
        }
        path.reverse();
        (dist[end], path)
    }

    /// Maximum forward-path latency from `src`'s φ node to every carried
    /// scalar's final definition, with the realizing path. Node
    /// latencies accumulate over the path (the φ itself contributes 0).
    fn paths_from_phi(&self, src_phi: usize) -> (Vec<Option<f64>>, Vec<Option<usize>>) {
        let n = self.nodes.len();
        let mut dist: Vec<Option<f64>> = vec![None; n];
        let mut pred: Vec<Option<usize>> = vec![None; n];
        dist[src_phi] = Some(0.0);
        for id in (src_phi + 1)..n {
            let mut best: Option<(f64, usize)> = None;
            for &i in &self.nodes[id].inputs {
                if let Some(d) = dist[i] {
                    if best.map(|(b, _)| d > b).unwrap_or(true) {
                        best = Some((d, i));
                    }
                }
            }
            if let Some((d, from)) = best {
                dist[id] = Some(d + self.nodes[id].latency);
                pred[id] = Some(from);
            }
        }
        (dist, pred)
    }

    /// Enumerate the loop-carried dependency chains: every simple cycle
    /// through back-edges, each reported once (rooted at its smallest
    /// carried scalar), with its cycle-mean latency per iteration and
    /// the node path realizing it. Chains are ordered unbroken-first,
    /// then by descending latency, then by name — deterministically.
    pub fn chains(&self, break_reductions: bool) -> Vec<Chain> {
        // reduced graph over carried scalars: weight(src → dst) = max
        // forward-path latency φ_src → final_def(dst)
        let vars: Vec<&String> = self.phi.iter().map(|(c, _)| c).collect();
        let def_of: BTreeMap<&String, usize> = self
            .back
            .iter()
            .map(|&(def, phi_id)| {
                let (c, _) = self.phi.iter().find(|(_, p)| *p == phi_id).unwrap();
                (c, def)
            })
            .collect();
        // edge (src index, dst index) → (latency, path node ids)
        let mut edges: HashMap<(usize, usize), (f64, Vec<usize>)> = HashMap::new();
        for (si, (_, src_phi)) in self.phi.iter().enumerate() {
            let (dist, pred) = self.paths_from_phi(*src_phi);
            for (di, dst) in vars.iter().enumerate() {
                let Some(&def) = def_of.get(dst) else { continue };
                let Some(w) = dist[def] else { continue };
                let mut path = Vec::new();
                let mut cur = Some(def);
                while let Some(id) = cur {
                    if id == *src_phi {
                        break;
                    }
                    path.push(id);
                    cur = pred[id];
                }
                path.reverse();
                edges.insert((si, di), (w, path));
            }
        }

        // simple cycles, each rooted at its minimal var index: DFS that
        // only visits indices above the root
        let mut chains = Vec::new();
        let k = vars.len();
        for root in 0..k {
            let mut stack: Vec<(usize, f64, Vec<usize>, Vec<usize>)> =
                vec![(root, 0.0, vec![root], Vec::new())];
            while let Some((cur, lat, trail, nodes_so_far)) = stack.pop() {
                for next in root..k {
                    let Some((w, epath)) = edges.get(&(cur, next)) else { continue };
                    if next == root {
                        let cycle_len = trail.len() as f64;
                        let mut path = nodes_so_far.clone();
                        path.extend(epath.iter().copied());
                        let var_names: Vec<String> =
                            trail.iter().map(|&i| vars[i].clone()).collect();
                        let broken = break_reductions
                            && trail.len() == 1
                            && self.breakable.contains(vars[root].as_str());
                        chains.push(Chain {
                            vars: var_names,
                            latency_per_it: (lat + w) / cycle_len,
                            broken,
                            path,
                        });
                    } else if !trail.contains(&next) {
                        let mut t = trail.clone();
                        t.push(next);
                        let mut p = nodes_so_far.clone();
                        p.extend(epath.iter().copied());
                        stack.push((next, lat + w, t, p));
                    }
                }
            }
        }
        chains.sort_by(|a, b| {
            a.broken
                .cmp(&b.broken)
                .then(b.latency_per_it.total_cmp(&a.latency_per_it))
                .then(a.vars.cmp(&b.vars))
        });
        chains
    }

    /// Maximum cycle-mean latency per iteration over chains that modulo
    /// variable expansion cannot break — the LCD bound that gates
    /// vectorization and floors T_OL.
    pub fn unbreakable_cycle_mean(&self, break_reductions: bool) -> f64 {
        self.chains(break_reductions)
            .iter()
            .filter(|c| !c.broken)
            .map(|c| c.latency_per_it)
            .fold(0.0, f64::max)
    }
}
