//! ISA abstraction for the in-core engine (DESIGN.md §4).
//!
//! The port/throughput model and the dependency DAG both consume
//! instructions as abstract µop classes ([`UopClass`]); this module
//! resolves those classes to a concrete instruction selection — mnemonic,
//! latency, and (optionally) an explicit port map — from the machine
//! YAML instead of hard-coded x86 assumptions:
//!
//! * the `isa:` block names the [`IsaFamily`] (`family: aarch64`), which
//!   picks the default mnemonic table (AVX spellings for x86, SVE
//!   spellings for AArch64),
//! * the `latency:` block and the `DIV` throughput table provide the
//!   default per-class latencies,
//! * an optional top-level `instructions:` table overrides mnemonic,
//!   latency, and port assignment per class (the OSACA-style
//!   per-instruction database, reduced to the classes this model uses).

use crate::machine::{MachineModel, UopClass};
use std::collections::HashMap;

/// Instruction-set family of a machine description. Selection of
/// default mnemonics (and nothing else) hangs off this: latencies and
/// port maps always come from the machine file itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IsaFamily {
    /// x86-64 with AVX/AVX2 SIMD (the paper's SNB/HSW testbed).
    X86,
    /// AArch64 with SVE SIMD (e.g. Fujitsu A64FX).
    AArch64,
}

impl IsaFamily {
    /// Parse the `isa: family:` spelling of a machine file.
    pub fn parse(s: &str) -> Option<IsaFamily> {
        match s.to_ascii_lowercase().as_str() {
            "x86" | "x86_64" | "x86-64" | "amd64" => Some(IsaFamily::X86),
            "aarch64" | "arm64" | "armv8" | "sve" => Some(IsaFamily::AArch64),
            _ => None,
        }
    }

    /// Stable label used in reports and the `/metrics` isa label.
    pub fn name(self) -> &'static str {
        match self {
            IsaFamily::X86 => "x86",
            IsaFamily::AArch64 => "aarch64",
        }
    }
}

/// Per-class override parsed from a machine file's `instructions:` table.
/// Absent members fall back to the family/latency-block defaults.
#[derive(Debug, Clone, Default)]
pub struct InstrOverride {
    pub mnemonic: Option<String>,
    pub latency: Option<f64>,
    /// Explicit port assignment; empty means "derive from the port
    /// table's accept lists" like every class without an override.
    pub ports: Vec<String>,
}

/// One resolved instruction: what the machine executes for a µop class.
#[derive(Debug, Clone, PartialEq)]
pub struct InstrDef {
    pub mnemonic: String,
    /// Result latency in cycles (0 for stores, which feed nothing).
    pub latency: f64,
    /// Explicit port names, empty when the port table's accept lists
    /// govern placement.
    pub ports: Vec<String>,
}

/// The resolved instruction selection of one machine at one SIMD width:
/// every [`UopClass`] maps to an [`InstrDef`].
#[derive(Debug, Clone)]
pub struct IsaSpec {
    pub family: IsaFamily,
    defs: HashMap<UopClass, InstrDef>,
}

const ALL_CLASSES: [UopClass; 9] = [
    UopClass::Add,
    UopClass::Mul,
    UopClass::Div,
    UopClass::Fma,
    UopClass::Load,
    UopClass::Store,
    UopClass::Agu,
    UopClass::StAgu,
    UopClass::Misc,
];

fn default_mnemonic(family: IsaFamily, class: UopClass, vectorized: bool) -> &'static str {
    match (family, vectorized) {
        (IsaFamily::X86, true) => match class {
            UopClass::Add => "vaddpd",
            UopClass::Mul => "vmulpd",
            UopClass::Div => "vdivpd",
            UopClass::Fma => "vfmadd213pd",
            UopClass::Load => "vmovupd",
            UopClass::Store => "vmovupd",
            UopClass::Agu | UopClass::StAgu => "lea",
            UopClass::Misc => "misc",
        },
        (IsaFamily::X86, false) => match class {
            UopClass::Add => "addsd",
            UopClass::Mul => "mulsd",
            UopClass::Div => "divsd",
            UopClass::Fma => "vfmadd213sd",
            UopClass::Load => "movsd",
            UopClass::Store => "movsd",
            UopClass::Agu | UopClass::StAgu => "lea",
            UopClass::Misc => "misc",
        },
        (IsaFamily::AArch64, true) => match class {
            UopClass::Add => "fadd",
            UopClass::Mul => "fmul",
            UopClass::Div => "fdiv",
            UopClass::Fma => "fmla",
            UopClass::Load => "ld1d",
            UopClass::Store => "st1d",
            UopClass::Agu | UopClass::StAgu => "agu",
            UopClass::Misc => "misc",
        },
        (IsaFamily::AArch64, false) => match class {
            UopClass::Add => "fadd",
            UopClass::Mul => "fmul",
            UopClass::Div => "fdiv",
            UopClass::Fma => "fmadd",
            UopClass::Load => "ldr",
            UopClass::Store => "str",
            UopClass::Agu | UopClass::StAgu => "agu",
            UopClass::Misc => "misc",
        },
    }
}

impl IsaSpec {
    /// Resolve the instruction selection of a machine at the given SIMD
    /// width: family defaults for mnemonics, the `latency:` block (plus
    /// the scalar `DIV` throughput) for latencies, then the machine's
    /// `instructions:` overrides on top.
    pub fn resolve(machine: &MachineModel, vectorized: bool) -> IsaSpec {
        let family = machine.isa.family;
        let default_latency = |class: UopClass| -> f64 {
            match class {
                UopClass::Add => machine.latency.add,
                UopClass::Mul => machine.latency.mul,
                UopClass::Fma => machine.latency.fma,
                UopClass::Load => machine.latency.load,
                UopClass::Div => machine.div_cycles(1),
                // stores feed nothing; address/overhead µops are not on
                // value dependency chains
                UopClass::Store | UopClass::Agu | UopClass::StAgu | UopClass::Misc => 0.0,
            }
        };
        let mut defs = HashMap::new();
        for class in ALL_CLASSES {
            let mut def = InstrDef {
                mnemonic: default_mnemonic(family, class, vectorized).to_string(),
                latency: default_latency(class),
                ports: Vec::new(),
            };
            if let Some(ov) = machine.instructions.iter().find(|(c, _)| *c == class) {
                if let Some(m) = &ov.1.mnemonic {
                    def.mnemonic = m.clone();
                }
                if let Some(l) = ov.1.latency {
                    def.latency = l;
                }
                if !ov.1.ports.is_empty() {
                    def.ports = ov.1.ports.clone();
                }
            }
            defs.insert(class, def);
        }
        IsaSpec { family, defs }
    }

    /// The resolved instruction for a class.
    pub fn def(&self, class: UopClass) -> &InstrDef {
        &self.defs[&class]
    }

    /// Result latency of a class in cycles.
    pub fn latency(&self, class: UopClass) -> f64 {
        self.defs[&class].latency
    }

    /// Mnemonic of a class (for chain/report rendering).
    pub fn mnemonic(&self, class: UopClass) -> &str {
        &self.defs[&class].mnemonic
    }

    /// Explicit port assignment of a class; empty when the machine's
    /// port-table accept lists govern placement.
    pub fn port_override(&self, class: UopClass) -> &[String] {
        &self.defs[&class].ports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_spellings_parse() {
        assert_eq!(IsaFamily::parse("x86_64"), Some(IsaFamily::X86));
        assert_eq!(IsaFamily::parse("AArch64"), Some(IsaFamily::AArch64));
        assert_eq!(IsaFamily::parse("sve"), Some(IsaFamily::AArch64));
        assert_eq!(IsaFamily::parse("riscv"), None);
    }

    #[test]
    fn x86_defaults_from_latency_block() {
        let m = MachineModel::snb();
        let spec = IsaSpec::resolve(&m, true);
        assert_eq!(spec.family, IsaFamily::X86);
        assert_eq!(spec.mnemonic(UopClass::Add), "vaddpd");
        assert_eq!(spec.latency(UopClass::Add), 3.0);
        assert_eq!(spec.latency(UopClass::Mul), 5.0);
        assert_eq!(spec.latency(UopClass::Load), 4.0);
        // scalar DIV latency comes from the throughput table
        assert_eq!(spec.latency(UopClass::Div), 22.0);
        assert!(spec.port_override(UopClass::Add).is_empty());
        let scalar = IsaSpec::resolve(&m, false);
        assert_eq!(scalar.mnemonic(UopClass::Add), "addsd");
    }
}
