//! Paper **Figure 2**: cache usage prediction for the 2D 5-point Jacobi
//! with N = 40 — which access is served by which memory level, and the
//! layer-condition table.
//!
//! ```sh
//! cargo run --release --example cache_viz [N]
//! ```

use kerncraft::cache::CachePredictor;
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::reference::KERNEL_2D5PT;
use kerncraft::report;
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    let n: i64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    // The paper's Fig. 2 uses a hypothetical machine whose caches satisfy
    // the layer condition in L3 and L2 but not in L1. A 40-wide row on
    // real SNB caches satisfies it everywhere, so we also print N = 6000
    // (the Table 5 configuration) for the interesting case.
    let machine = MachineModel::snb();
    let program = parse(KERNEL_2D5PT)?;
    for n in [n, 6000] {
        let consts: HashMap<String, i64> =
            [("N".to_string(), n), ("M".to_string(), n.max(40))].into_iter().collect();
        let analysis = KernelAnalysis::from_program(&program, &consts)?;
        let traffic = CachePredictor::new(&machine).predict(&analysis)?;
        println!("--- 2D-5pt Jacobi, N = {n} (SNB) ---");
        print!("{}", report::cache_viz(&analysis, &traffic));
        println!();
    }
    Ok(())
}
