//! End-to-end validation driver — the full pipeline on all five paper
//! kernels, proving every layer composes:
//!
//! 1. **Analytic models** (L3 Rust): parse the C kernel → port model +
//!    cache prediction → ECM & Roofline predictions for SNB;
//! 2. **Virtual testbed** (L3 Rust): trace-driven "measurement" on the
//!    simulated SNB — the paper's Benchmark column;
//! 3. **Native host run** (L3 Rust): the same loop timed on this CPU;
//! 4. **PJRT run** (L1/L2 → AOT → L3): the JAX/Pallas implementation of
//!    the kernel, lowered at build time to HLO text, loaded and executed
//!    through the PJRT C API — Python is NOT running here.
//!
//! The headline metric (paper Table 5): model-vs-measurement agreement in
//! cy/CL on the virtual testbed, plus host-side sanity from the real
//! runs. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example validate
//! ```

use kerncraft::bench_mode;
use kerncraft::cache::CachePredictor;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::{reference, EcmModel, RooflineModel};
use kerncraft::sim::VirtualTestbed;
use std::collections::HashMap;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let machine = MachineModel::snb();
    let policy = CodegenPolicy::for_machine(&machine);
    let artifacts = Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.tsv").exists();
    if !have_artifacts {
        eprintln!("note: artifacts/ missing — run `make artifacts` for the PJRT column");
    }

    println!("=== end-to-end validation: model vs virtual testbed vs host runs (SNB models) ===");
    println!(
        "{:<11} | {:>9} {:>9} | {:>11} {:>6} | {:>12} | {:>12}",
        "kernel", "ECM cy/CL", "Roofline", "virt. cy/CL", "Δ%", "native It/s", "PJRT It/s"
    );

    let pjrt_names = [
        ("2D-5pt", "jacobi2d"),
        ("UXX", "uxx"),
        ("long-range", "long_range"),
        ("Kahan-dot", "kahan_ddot"),
        ("triad", "triad"),
    ];

    let mut worst = 0.0f64;
    for tag in reference::kernel_tags() {
        let row = reference::TABLE5
            .iter()
            .find(|r| r.kernel == tag && r.arch == "SNB")
            .unwrap();
        let src = reference::kernel_source(tag).unwrap();
        let consts: HashMap<String, i64> =
            row.constants.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        let analysis = KernelAnalysis::from_program(&parse(src)?, &consts)?;

        // 1. analytic models
        let pm = PortModel::analyze(&analysis, &machine, &policy)?;
        let traffic = CachePredictor::new(&machine).predict(&analysis)?;
        let ecm = EcmModel::build(&pm, &traffic, &machine)?;
        let roofline = RooflineModel::build(&analysis, &traffic, &machine, Some(&pm))?;

        // 2. virtual testbed measurement
        let mut tb = VirtualTestbed::new(&machine);
        tb.max_iterations = 1_500_000;
        let sim = tb.run(&analysis)?;
        let delta = (sim.cy_per_cl - ecm.t_mem()) / ecm.t_mem() * 100.0;
        worst = worst.max(delta.abs());

        // 3. native host run (smaller sizes keep this quick)
        let native_consts: Vec<(&str, i64)> = row
            .constants
            .iter()
            .map(|(k, v)| (*k, (*v).min(2_000_000)))
            .collect();
        let native = bench_mode::run_native(tag, &native_consts, 3)?;

        // 4. PJRT artifact run (the three-layer path)
        let pjrt = if have_artifacts {
            let name = pjrt_names.iter().find(|(t, _)| t == &tag).unwrap().1;
            match bench_mode::run_pjrt(artifacts, name, 3) {
                Ok(r) => format!("{:.3e}", r.it_per_s),
                Err(e) => format!("err: {e}"),
            }
        } else {
            "n/a".to_string()
        };

        println!(
            "{:<11} | {:>9.1} {:>9.1} | {:>11.1} {:>+5.1}% | {:>12.3e} | {:>12}",
            tag,
            ecm.t_mem(),
            roofline.prediction(),
            sim.cy_per_cl,
            delta,
            native.it_per_s,
            pjrt
        );
    }
    println!("worst |virtual - ECM| deviation: {worst:.1}%");
    println!("validate OK — record these rows in EXPERIMENTS.md");
    Ok(())
}
