//! Figure 3-style sweep driver for ANY of the shipped kernels: ECM
//! contributions and layer conditions as the problem size grows — the
//! parallel [`kerncraft::sweep::SweepEngine`] mapping requests through
//! one shared [`kerncraft::session::Session`] (also used up front to
//! screen out points whose halo does not fit).
//!
//! ```sh
//! cargo run --release --example stencil_sweep -- [kernel-tag] [machine] [predictor]
//! # e.g.: cargo run --release --example stencil_sweep -- 2D-5pt HSW auto
//! ```

use kerncraft::cache::CachePredictorKind;
use kerncraft::models::reference;
use kerncraft::session::{KernelSpec, ModelKind, Session};
use kerncraft::sweep::{SweepEngine, SweepJob};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let tag = std::env::args().nth(1).unwrap_or_else(|| "long-range".to_string());
    let arch = std::env::args().nth(2).unwrap_or_else(|| "SNB".to_string());
    let predictor = std::env::args()
        .nth(3)
        .map(|s| {
            CachePredictorKind::parse(&s)
                .ok_or_else(|| anyhow::anyhow!("unknown predictor {s} (offsets|lc|auto)"))
        })
        .transpose()?
        .unwrap_or(CachePredictorKind::Auto);
    let src = reference::kernel_source(&tag)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel {tag} (use a Table 5 tag)"))?;
    let source: Arc<str> = Arc::from(src);

    let mut jobs = Vec::new();
    for exp in 4..13 {
        let n: i64 = 1 << exp;
        jobs.push(SweepJob {
            label: tag.clone(),
            source: source.clone(),
            machine: arch.clone(),
            cores: 1,
            constants: [("N".to_string(), n), ("M".to_string(), n.min(600))]
                .into_iter()
                .collect(),
            predictor,
            model: ModelKind::Ecm,
        });
    }

    // Points whose halo does not fit are dropped up front (the engine
    // fails the whole batch on any error, by design): a point is viable
    // iff the static analysis binds and every loop has iterations. The
    // screening session is reused by the engine run below, so the parse
    // and every surviving analysis are already cached.
    let session = Session::new();
    let spec = KernelSpec::source(tag.as_str(), source.clone());
    jobs.retain(|j| {
        session
            .kernel_analysis(&spec, &j.constants)
            .map(|a| a.loops.iter().all(|l| l.trip() > 0))
            .unwrap_or(false)
    });

    let out = SweepEngine::new().run_with_session(&session, &jobs)?;
    println!("ECM sweep for {tag} on {arch} ({} predictor)", predictor.name());
    println!(
        "{:>7} | {:>7} {:>7} | {:>8} {:>8} {:>8} | {:>9} | sat | lc/walk | bands",
        "N", "T_OL", "T_nOL", "L1L2", "L2L3", "L3Mem", "ECM_Mem"
    );
    for row in &out.rows {
        let sat = if row.saturation_cores == u32::MAX {
            "inf".to_string()
        } else {
            row.saturation_cores.to_string()
        };
        println!(
            "{:>7} | {:>7.1} {:>7.1} | {:>8.1} {:>8.1} {:>8.1} | {:>9.1} | {:>3} | {:>3}/{:<4} | {}",
            row.constants["N"],
            row.t_ol,
            row.t_nol,
            row.links[0].2,
            row.links[1].2,
            row.links[2].2,
            row.t_ecm_mem,
            sat,
            row.lc_fast_levels,
            row.walk_levels,
            row.lc_breakpoints.join(" ")
        );
    }
    println!(
        "memo: program {}h/{}m  analysis {}h/{}m  incore {}h/{}m  ({} threads)",
        out.stats.program_hits,
        out.stats.program_misses,
        out.stats.analysis_hits,
        out.stats.analysis_misses,
        out.stats.incore_hits,
        out.stats.incore_misses,
        out.threads_used
    );
    Ok(())
}
