//! Figure 3-style sweep driver for ANY of the shipped kernels: ECM
//! contributions and layer conditions as the problem size grows.
//!
//! ```sh
//! cargo run --release --example stencil_sweep -- [kernel-tag] [machine]
//! # e.g.: cargo run --release --example stencil_sweep -- 2D-5pt HSW
//! ```

use kerncraft::cache::CachePredictor;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::{reference, EcmModel};
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    let tag = std::env::args().nth(1).unwrap_or_else(|| "long-range".to_string());
    let arch = std::env::args().nth(2).unwrap_or_else(|| "SNB".to_string());
    let machine = MachineModel::builtin(&arch)
        .ok_or_else(|| anyhow::anyhow!("unknown machine {arch}"))?;
    let src = reference::kernel_source(&tag)
        .ok_or_else(|| anyhow::anyhow!("unknown kernel {tag} (use a Table 5 tag)"))?;
    let program = parse(src)?;
    let policy = CodegenPolicy::for_machine(&machine);

    println!("ECM sweep for {tag} on {arch}");
    println!(
        "{:>7} | {:>7} {:>7} | {:>8} {:>8} {:>8} | {:>9} | sat.cores",
        "N", "T_OL", "T_nOL", "L1L2", "L2L3", "L3Mem", "ECM_Mem"
    );
    for exp in 4..13 {
        let n: i64 = 1 << exp;
        let mut consts: HashMap<String, i64> = HashMap::new();
        consts.insert("N".to_string(), n);
        consts.insert("M".to_string(), n.min(600)); // keep 3D cases tractable
        let Ok(analysis) = KernelAnalysis::from_program(&program, &consts) else {
            continue;
        };
        if analysis.loops.iter().any(|l| l.trip() <= 0) {
            continue;
        }
        let pm = PortModel::analyze(&analysis, &machine, &policy)?;
        let traffic = CachePredictor::new(&machine).predict(&analysis)?;
        let ecm = EcmModel::build(&pm, &traffic, &machine)?;
        println!(
            "{:>7} | {:>7.1} {:>7.1} | {:>8.1} {:>8.1} {:>8.1} | {:>9.1} | {}",
            n,
            ecm.t_ol,
            ecm.t_nol,
            ecm.contributions[0].cycles,
            ecm.contributions[1].cycles,
            ecm.contributions[2].cycles,
            ecm.t_mem(),
            ecm.saturation_cores()
        );
    }
    Ok(())
}
