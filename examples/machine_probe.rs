//! `likwid_auto_bench.py` substitute: probe the host topology, run the
//! STREAM-style microbenchmark suite per memory level, and emit a machine
//! description file skeleton for this host.
//!
//! ```sh
//! cargo run --release --example machine_probe > machines/host.yml
//! ```

use kerncraft::machine::topology::Topology;
use kerncraft::microbench;

fn main() {
    let topo = Topology::probe();
    eprintln!(
        "probed: {} — {} cores, {} sockets, {} caches",
        topo.model_name,
        topo.cores,
        topo.sockets,
        topo.caches.len()
    );

    // machine-file skeleton (ports/latencies need manual attention, as the
    // paper notes for its own auto-bench script)
    let mut yml = topo.to_machine_yaml();

    // measured benchmark section
    let mut sizes: Vec<(String, u64)> = topo
        .caches
        .iter()
        .map(|c| (format!("L{}", c.level), c.size_bytes))
        .collect();
    sizes.sort_by_key(|(_, s)| *s);
    sizes.dedup_by(|a, b| a.0 == b.0);
    // memory level: 8x the largest cache
    let mem_size = sizes.last().map(|(_, s)| s * 8).unwrap_or(256 << 20);
    sizes.push(("MEM".to_string(), mem_size));

    eprintln!("running microbenchmarks (this takes a few seconds)...");
    yml.push_str("\nbenchmarks:\n  kernels:\n");
    yml.push_str("    load:   {read streams: 1, read+write streams: 0, write streams: 0, FLOPs per iteration: 0}\n");
    yml.push_str("    copy:   {read streams: 1, read+write streams: 0, write streams: 1, FLOPs per iteration: 0}\n");
    yml.push_str("    update: {read streams: 0, read+write streams: 1, write streams: 0, FLOPs per iteration: 0}\n");
    yml.push_str("    daxpy:  {read streams: 1, read+write streams: 1, write streams: 0, FLOPs per iteration: 2}\n");
    yml.push_str("    triad:  {read streams: 3, read+write streams: 0, write streams: 1, FLOPs per iteration: 2}\n");
    yml.push_str("  measurements:\n");
    for (level, samples) in microbench::sweep_levels(&sizes) {
        for s in samples {
            yml.push_str(&format!(
                "    - {{level: {}, kernel: {}, bandwidth GB/s: [{:.1}]}}\n",
                level,
                s.kernel.name(),
                s.bandwidth_bs / 1e9
            ));
            eprintln!(
                "  {} {}: {:.1} GB/s (working set {} kB)",
                level,
                s.kernel.name(),
                s.bandwidth_bs / 1e9,
                s.working_set / 1024
            );
        }
    }
    println!("{yml}");
    eprintln!("wrote machine file skeleton to stdout");
}
