//! Quickstart: the paper's Listing 5 session — analyze the 2D 5-point
//! Jacobi kernel on Sandy Bridge with the ECM and Roofline models.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use kerncraft::cli;

fn main() -> anyhow::Result<()> {
    let argv = |s: &str| s.split_whitespace().map(str::to_string).collect::<Vec<_>>();

    println!("$ kerncraft -p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000\n");
    print!("{}", cli::run(&argv("-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 -v"))?);

    println!("\n$ kerncraft -p RooflinePort --unit cy/CL --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000\n");
    print!(
        "{}",
        cli::run(&argv(
            "-p RooflinePort --unit cy/CL --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000"
        ))?
    );

    println!("\npaper reference: ECM {{9.5 ‖ 8 | 10 | 6 | 12.7}} = 36.7 cy/CL, Roofline 29.8 cy/CL");
    Ok(())
}
