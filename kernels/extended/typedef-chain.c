typedef double real;
typedef real scalar;

scalar a[N], b[N];
scalar q;

for (size_t i = 0; i < N; ++i)
    a[i] = q * b[i];
