#define ROWS 512
#define COLS 512

double a[ROWS][COLS], b[ROWS][COLS];

for (int j = 1; j < ROWS - 1; ++j)
    for (int i = 1; i < COLS - 1; ++i)
        b[j][i] = a[j][i] * 0.5;
