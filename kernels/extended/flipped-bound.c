double a[N], b[N], s;

for (int i = 0; N > i; ++i)
    a[i] = s * b[i];
