double a[N], b[N];

for (int i = 0; i < N; i += 2)
    a[i] = b[i];
