double a[N], b[N], c[N], d[N], s, t;

for (int i = 0; i < N; ++i) {
    a[i] = s * c[i] + d[i];
    b[i] = t * c[i] - d[i];
}
