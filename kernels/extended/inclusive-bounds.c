double a[N][N], b[N][N];

for (int j = 1; j <= N - 2; j++)
    for (int i = 1; i <= N - 2; i++)
        b[j][i] = 0.25 * (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]);
