double a[N], b[N], c[N];

for (int i = 0; i < N; ++i) {
    { a[i] = b[i] + 1.0; }
    { c[i] = b[i] - 1.0; }
}
