double a[N], b[N], t;

for (int i = 0; i < N; ++i) {
    if (b[i] > 0.0)
        a[i] = b[i] * t;
    else
        a[i] = 0.0;
}
