typedef double real;

real x[N], y[N];
real alpha;

for (int i = 0; i < N; ++i)
    y[i] = alpha * x[i] + y[i];
