double a[N], b[N];

for (int i = 0; i < N; i = i + 4)
    a[i] = 2.0 * b[i];
