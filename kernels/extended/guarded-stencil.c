double a[N][N], b[N][N], lo, hi;

for (int j = 1; j < N - 1; ++j)
    for (int i = 1; i < N - 1; ++i)
        if (a[j][i] > lo && a[j][i] < hi)
            b[j][i] = a[j][i];
