double a[N];
float b[N];

for (int i = 0; i < N; ++i)
    a[i] = (double)b[i] * 0.5;
