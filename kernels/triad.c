double a[N], b[N], c[N], d[N];

for (int i = 0; i < N; i++)
    a[i] = b[i] + c[i] * d[i];
