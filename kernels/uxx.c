double u1[M][N][N], d1[M][N][N], xx[M][N][N], xy[M][N][N], xz[M][N][N];
double c1, c2, d, dth;

for (int k = 2; k < M - 2; k++) {
    for (int j = 2; j < N - 2; j++) {
        for (int i = 2; i < N - 2; i++) {
            d = (d1[k-1][j][i] + d1[k-1][j-1][i] + d1[k][j][i] + d1[k][j-1][i]) * 0.25;
            u1[k][j][i] = u1[k][j][i] + (dth / d)
                * (c1 * (xx[k][j][i] - xx[k][j][i-1])
                 + c2 * (xx[k][j][i+1] - xx[k][j][i-2])
                 + c1 * (xy[k][j][i] - xy[k][j-1][i])
                 + c2 * (xy[k][j+1][i] - xy[k][j-2][i])
                 + c1 * (xz[k][j][i] - xz[k-1][j][i])
                 + c2 * (xz[k+1][j][i] - xz[k-2][j][i]));
        }
    }
}
