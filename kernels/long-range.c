double u[M][N][N], v[M][N][N], roc[M][N][N];
double c0, c1, c2, c3, c4, lap;

for (int k = 4; k < M - 4; k++) {
    for (int j = 4; j < N - 4; j++) {
        for (int i = 4; i < N - 4; i++) {
            lap = c0 * v[k][j][i]
                + c1 * (v[k][j][i+1] + v[k][j][i-1] + v[k][j+1][i] + v[k][j-1][i] + v[k+1][j][i] + v[k-1][j][i])
                + c2 * (v[k][j][i+2] + v[k][j][i-2] + v[k][j+2][i] + v[k][j-2][i] + v[k+2][j][i] + v[k-2][j][i])
                + c3 * (v[k][j][i+3] + v[k][j][i-3] + v[k][j+3][i] + v[k][j-3][i] + v[k+3][j][i] + v[k-3][j][i])
                + c4 * (v[k][j][i+4] + v[k][j][i-4] + v[k][j+4][i] + v[k][j-4][i] + v[k+4][j][i] + v[k-4][j][i]);
            u[k][j][i] = 2.0 * v[k][j][i] - u[k][j][i] + roc[k][j][i] * lap;
        }
    }
}
