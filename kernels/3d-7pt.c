double a[M][N][P], b[M][N][P], s;

for (int k = 1; k < M - 1; k++)
    for (int j = 1; j < N - 1; j++)
        for (int i = 1; i < P - 1; i++)
            b[k][j][i] = (a[k][j][i-1] + a[k][j][i+1] + a[k][j-1][i]
                + a[k][j+1][i] + a[k-1][j][i] + a[k+1][j][i]) * s;
