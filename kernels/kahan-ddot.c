double a[N], b[N], c;
double sum, prod, t, y;

for (int i = 0; i < N; ++i) {
    prod = a[i] * b[i];
    y = prod - c;
    t = sum + y;
    c = (t - sum) - y;
    sum = t;
}
