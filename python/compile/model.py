"""L2: JAX benchmark wrappers around the L1 Pallas kernels.

Each ``<name>_bench`` repeats the kernel ``reps`` times inside a
``lax.fori_loop`` with a data-dependent carry, so XLA cannot elide any
sweep — this is the computation the Rust Benchmark mode times after AOT
lowering (Python never runs on the measurement path).
"""

import jax
import jax.numpy as jnp

from .kernels import pallas_kernels as pk


def jacobi2d_step(a, s):
    """One Jacobi sweep (Pallas)."""
    return pk.jacobi2d(a, s)


def jacobi2d_bench(a, s, reps: int):
    """`reps` ping-pong Jacobi sweeps."""

    def body(_, carry):
        return pk.jacobi2d(carry, s)

    return jax.lax.fori_loop(0, reps, body, a)


def triad_step(b, c, d):
    return pk.triad(b, c, d)


def triad_bench(b, c, d, reps: int):
    def body(_, carry):
        return pk.triad(carry, c, d)

    return jax.lax.fori_loop(0, reps, body, b)


def kahan_ddot_step(a, b):
    s, c = pk.kahan_ddot(a, b)
    return s, c


def kahan_ddot_bench(a, b, reps: int):
    def body(_, acc):
        s, _ = pk.kahan_ddot(a + acc * 1e-30, b)
        return s

    return jax.lax.fori_loop(0, reps, body, jnp.zeros((), a.dtype))


def uxx_step(u1, d1, xx, xy, xz, c1, c2, dth):
    return pk.uxx(u1, d1, xx, xy, xz, c1, c2, dth)


def uxx_bench(u1, d1, xx, xy, xz, reps: int):
    def body(_, carry):
        return pk.uxx(carry, d1, xx, xy, xz, 0.5, 0.25, 0.1)

    return jax.lax.fori_loop(0, reps, body, u1)


def long_range_step(U, V, ROC, c):
    return pk.long_range(U, V, ROC, c)


def long_range_bench(U, V, ROC, reps: int):
    c = jnp.asarray([0.5, 0.2, 0.1, 0.05, 0.025], dtype=U.dtype)

    def body(_, carry):
        return pk.long_range(carry, V, ROC, c)

    return jax.lax.fori_loop(0, reps, body, U)
