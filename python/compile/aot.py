"""AOT lowering: JAX/Pallas benchmark graphs → XLA HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the ``xla`` crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``

Besides one ``<name>.hlo.txt`` per kernel a ``manifest.tsv`` is written
with everything the Rust Benchmark mode needs to time and normalize the
execution: input shapes/dtypes, repetitions per executable, inner
iterations per sweep, and source flops per iteration.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_defs():
    """(name, lowered_fn, arg_specs, reps, iters_per_sweep, flops_per_it)."""
    f = jnp.float64
    defs = []

    # 2D-5pt Jacobi: 258x256 grid, 20 ping-pong sweeps
    m, n, reps = 258, 256, 20
    defs.append(
        dict(
            name="jacobi2d",
            fn=lambda a, s, r=reps: (model.jacobi2d_bench(a, s, r),),
            args=[spec((m, n), f), spec((), f)],
            reps=reps,
            iters=(m - 2) * (n - 2),
            flops=4,
        )
    )

    # Schönauer triad: 2^20 elements, 20 sweeps
    nt, reps = 1 << 20, 20
    defs.append(
        dict(
            name="triad",
            fn=lambda b, c, d, r=reps: (model.triad_bench(b, c, d, r),),
            args=[spec((nt,), f)] * 3,
            reps=reps,
            iters=nt,
            flops=2,
        )
    )

    # Kahan dot product: 2^16 elements, 10 sweeps
    nk, reps = 1 << 16, 10
    defs.append(
        dict(
            name="kahan_ddot",
            fn=lambda a, b, r=reps: (model.kahan_ddot_bench(a, b, r),),
            args=[spec((nk,), f)] * 2,
            reps=reps,
            iters=nk,
            flops=5,
        )
    )

    # UXX: 36^3 with halo 2 → 32 interior planes, 5 sweeps
    mu, reps = 36, 5
    defs.append(
        dict(
            name="uxx",
            fn=lambda u1, d1, xx, xy, xz, r=reps: (
                model.uxx_bench(u1, d1, xx, xy, xz, r),
            ),
            args=[spec((mu, mu, mu), f)] * 5,
            reps=reps,
            iters=(mu - 4) ** 3,
            flops=16,
        )
    )

    # long-range: 40^3 with halo 4 → 32 interior planes, 5 sweeps
    ml, reps = 40, 5
    defs.append(
        dict(
            name="long_range",
            fn=lambda U, V, ROC, r=reps: (model.long_range_bench(U, V, ROC, r),),
            args=[spec((ml, ml, ml), f)] * 3,
            reps=reps,
            iters=(ml - 8) ** 3,
            flops=41,
        )
    )
    return defs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="lower a single kernel by name"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []
    for d in artifact_defs():
        if args.only and d["name"] != args.only:
            continue
        lowered = jax.jit(d["fn"]).lower(*d["args"])
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{d['name']}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        shapes = ";".join(
            f"{a.dtype}:{','.join(str(s) for s in a.shape)}" for a in d["args"]
        )
        manifest_rows.append(
            f"{d['name']}\t{d['name']}.hlo.txt\t{d['reps']}\t{d['iters']}\t{d['flops']}\t{shapes}"
        )
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    header = "name\tfile\treps\titers_per_sweep\tflops_per_iter\tinputs\n"
    with open(manifest, "w") as fh:
        fh.write(header)
        fh.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
