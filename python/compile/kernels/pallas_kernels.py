"""L1: Pallas implementations of the five paper kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's layer
condition — "enough consecutive layers of the grid must fit in cache
level k" — becomes the BlockSpec choice here. Every stencil is tiled so
one block plus its halo fits VMEM; halos are materialized by passing
pre-shifted views of the input (sliced in the L2 wrapper), which keeps
every BlockSpec a plain non-overlapping tiling.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowering produces plain
HLO that the Rust runtime loads and executes (see gen_hlo.py in
/opt/xla-example). Correctness is pinned against ``ref.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ---------------------------------------------------------------------------
# 2D 5-point Jacobi (paper Listing 3)
# ---------------------------------------------------------------------------


def _jacobi_kernel(top_ref, mid_ref, bot_ref, s_ref, out_ref):
    top = top_ref[...]
    mid = mid_ref[...]
    bot = bot_ref[...]
    s = s_ref[0]
    res = (mid[:, :-2] + mid[:, 2:] + top[:, 1:-1] + bot[:, 1:-1]) * s
    out_ref[...] = jnp.pad(res, ((0, 0), (1, 1)))


def jacobi2d(a, s, block_rows=None):
    """One Jacobi sweep; returns an array shaped like ``a`` with the
    boundary zeroed (matching ``ref.jacobi2d``)."""
    m, n = a.shape
    rows = m - 2
    if block_rows is None:
        block_rows = _pick_block(rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    s_arr = jnp.asarray([s], dtype=a.dtype)
    spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    interior = pl.pallas_call(
        _jacobi_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), a.dtype),
        interpret=True,
    )(a[:-2], a[1:-1], a[2:], s_arr)
    return jnp.zeros_like(a).at[1:-1, :].set(interior)


# ---------------------------------------------------------------------------
# Schönauer triad (paper Listing 9)
# ---------------------------------------------------------------------------


def _triad_kernel(b_ref, c_ref, d_ref, a_ref):
    a_ref[...] = b_ref[...] + c_ref[...] * d_ref[...]


def triad(b, c, d, block=None):
    """a = b + c * d, tiled in 1D chunks."""
    (n,) = b.shape
    if block is None:
        block = _pick_block(n)
    assert n % block == 0, (n, block)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _triad_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(b, c, d)


# ---------------------------------------------------------------------------
# Kahan-compensated dot product (paper Listing 8)
# ---------------------------------------------------------------------------


def _kahan_kernel(a_ref, b_ref, out_ref):
    x = a_ref[...]
    y = b_ref[...]

    def body(carry, xy):
        s, c = carry
        prod = xy[0] * xy[1]
        yy = prod - c
        t = s + yy
        c_new = (t - s) - yy
        return (t, c_new), None

    (s, c), _ = jax.lax.scan(
        body, (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype)), (x, y)
    )
    out_ref[0, 0] = s
    out_ref[0, 1] = c

def kahan_ddot(a, b, block=None):
    """Blocked compensated dot product.

    Each block produces a compensated partial (sum, c); the partials are
    combined with a final sequential compensated pass. For block == n the
    result is bit-identical to ``ref.kahan_ddot``.
    """
    (n,) = a.shape
    if block is None:
        block = _pick_block(n)
    assert n % block == 0, (n, block)
    nblocks = n // block
    spec = pl.BlockSpec((block,), lambda i: (i,))
    partials = pl.pallas_call(
        _kahan_kernel,
        grid=(nblocks,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, 2), a.dtype),
        interpret=True,
    )(a, b)

    # combine block partials with the same compensated scheme; the first
    # partial seeds the accumulator so a single block is bit-identical to
    # the sequential reference
    def body(carry, p):
        s, c = carry
        y = p[0] - (c + p[1])
        t = s + y
        c_new = (t - s) - y
        return (t, c_new), None

    (s, c), _ = jax.lax.scan(
        body, (partials[0, 0], partials[0, 1]), partials[1:]
    )
    return s, c


# ---------------------------------------------------------------------------
# UXX stencil (paper Listing 6)
# ---------------------------------------------------------------------------


def _uxx_kernel(
    u1_ref, d1k_ref, d1km_ref, xx_ref, xy_ref, xzm2_ref, xzm1_ref, xz0_ref,
    xzp1_ref, coef_ref, out_ref,
):
    # refs are interior-k slices; j/i shifts happen inside the block
    c1 = coef_ref[0]
    c2 = coef_ref[1]
    dth = coef_ref[2]
    u1 = u1_ref[...]
    d1k = d1k_ref[...]   # d1 at plane k
    d1km = d1km_ref[...] # d1 at plane k-1
    xx = xx_ref[...]
    xy = xy_ref[...]

    def j(arr, dj):
        return arr[:, 2 + dj : arr.shape[1] - 2 + dj or None, 2:-2]

    def i(arr, di):
        return arr[:, 2:-2, 2 + di : arr.shape[2] - 2 + di or None]

    def ji(arr):
        return arr[:, 2:-2, 2:-2]

    d = (ji(d1km) + j(d1km, -1) + ji(d1k) + j(d1k, -1)) * 0.25
    res = ji(u1) + (dth / d) * (
        c1 * (ji(xx) - i(xx, -1))
        + c2 * (i(xx, 1) - i(xx, -2))
        + c1 * (ji(xy) - j(xy, -1))
        + c2 * (j(xy, 1) - j(xy, -2))
        + c1 * (ji(xz0_ref[...]) - ji(xzm1_ref[...]))
        + c2 * (ji(xzp1_ref[...]) - ji(xzm2_ref[...]))
    )
    out_ref[...] = res


def uxx(u1, d1, xx, xy, xz, c1, c2, dth, block_k=None):
    """UXX interior update; returns u1 with the interior replaced."""
    m, n, _ = u1.shape
    kk = m - 4  # interior planes
    if block_k is None:
        block_k = _pick_block(kk)
    assert kk % block_k == 0, (kk, block_k)
    grid = (kk // block_k,)
    full = pl.BlockSpec((block_k, n, n), lambda i: (i, 0, 0))
    coef = jnp.asarray([c1, c2, dth], dtype=u1.dtype)
    interior = pl.pallas_call(
        _uxx_kernel,
        grid=grid,
        in_specs=[full] * 9 + [pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=pl.BlockSpec(
            (block_k, n - 4, n - 4), lambda i: (i, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((kk, n - 4, n - 4), u1.dtype),
        interpret=True,
    )(
        u1[2:-2],
        d1[2:-2],
        d1[1:-3],
        xx[2:-2],
        xy[2:-2],
        xz[0:-4],
        xz[1:-3],
        xz[2:-2],
        xz[3:-1],
        coef,
    )
    return u1.at[2:-2, 2:-2, 2:-2].set(interior)


# ---------------------------------------------------------------------------
# Fourth-order long-range stencil (paper Listing 7)
# ---------------------------------------------------------------------------


def _long_range_kernel(*refs):
    # refs: U, ROC, V_km4..V_kp4 (9 k-shifted views), coef, out
    u_ref = refs[0]
    roc_ref = refs[1]
    v_refs = refs[2:11]
    coef_ref = refs[11]
    out_ref = refs[12]
    r = 4
    c = coef_ref[...]
    v0 = v_refs[r][...]  # dk = 0 view

    def j(arr, dj):
        return arr[:, r + dj : arr.shape[1] - r + dj or None, r:-r]

    def i(arr, di):
        return arr[:, r:-r, r + di : arr.shape[2] - r + di or None]

    def ji(arr):
        return arr[:, r:-r, r:-r]

    lap = c[0] * ji(v0)
    for o in range(1, 5):
        lap = lap + c[o] * (i(v0, o) + i(v0, -o))
        lap = lap + c[o] * (j(v0, o) + j(v0, -o))
        lap = lap + c[o] * (ji(v_refs[r + o][...]) + ji(v_refs[r - o][...]))
    out_ref[...] = 2.0 * ji(v0) - ji(u_ref[...]) + ji(roc_ref[...]) * lap


def long_range(U, V, ROC, c, block_k=None):
    """Fourth-order star stencil update of U (halo width 4)."""
    m, n, _ = U.shape
    r = 4
    kk = m - 2 * r
    if block_k is None:
        block_k = _pick_block(kk)
    assert kk % block_k == 0, (kk, block_k)
    grid = (kk // block_k,)
    full = pl.BlockSpec((block_k, n, n), lambda i: (i, 0, 0))
    coef = jnp.asarray(c, dtype=U.dtype)
    v_views = [V[r + dk : m - r + dk or None] for dk in range(-r, r + 1)]
    interior = pl.pallas_call(
        _long_range_kernel,
        grid=grid,
        in_specs=[full, full] + [full] * 9 + [pl.BlockSpec((5,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_k, n - 2 * r, n - 2 * r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, n - 2 * r, n - 2 * r), U.dtype),
        interpret=True,
    )(U[r:-r], ROC[r:-r], *v_views, coef)
    return U.at[r:-r, r:-r, r:-r].set(interior)


# ---------------------------------------------------------------------------


def _pick_block(n):
    """Largest divisor of n not exceeding a VMEM-friendly bound.

    Prefer LARGE blocks: every grid step lowers (under interpret=True) to
    one iteration of an XLA while loop, so tiny blocks turn streaming
    kernels into loop-overhead benchmarks (§Perf iteration 3: the triad
    artifact went from a 16384-step grid to 64 steps, >100x faster on the
    CPU PJRT runtime).
    """
    for cand in (16384, 4096, 1024, 256, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@functools.lru_cache(maxsize=None)
def kernel_names():
    return ("jacobi2d", "triad", "kahan_ddot", "uxx", "long_range")
