"""Pure-jnp oracles for the five paper kernels (L1 correctness baseline).

Each function mirrors the C kernel in `kernels/*.c` exactly — including
the boundary handling (untouched halo cells) — so the Pallas kernels and
the Rust virtual testbed all validate against the same semantics.
"""

import jax
import jax.numpy as jnp


def jacobi2d(a, s):
    """One 2D 5-point Jacobi sweep (paper Listing 3).

    b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s for the
    interior; the boundary of the output is zero.
    """
    interior = (a[1:-1, :-2] + a[1:-1, 2:] + a[:-2, 1:-1] + a[2:, 1:-1]) * s
    return jnp.zeros_like(a).at[1:-1, 1:-1].set(interior)


def triad(b, c, d):
    """Schönauer triad (paper Listing 9): a = b + c * d."""
    return b + c * d


def kahan_ddot(a, b):
    """Kahan-compensated dot product (paper Listing 8).

    Returns (sum, c) after the sequential compensated accumulation.
    """

    def body(carry, xy):
        s, c = carry
        x, y_in = xy
        prod = x * y_in
        y = prod - c
        t = s + y
        c_new = (t - s) - y
        return (t, c_new), None

    (s, c), _ = jax.lax.scan(
        body, (jnp.zeros((), a.dtype), jnp.zeros((), a.dtype)), (a, b)
    )
    return s, c


def _sh(arr, halo, dk=0, dj=0, di=0):
    """Shifted interior view with the given halo width."""
    return arr[
        slice(halo + dk, arr.shape[0] - halo + dk or None),
        slice(halo + dj, arr.shape[1] - halo + dj or None),
        slice(halo + di, arr.shape[2] - halo + di or None),
    ]


def uxx(u1, d1, xx, xy, xz, c1, c2, dth):
    """UXX stencil (paper Listing 6), interior update with halo width 2."""

    def sh(arr, dk=0, dj=0, di=0):
        return _sh(arr, 2, dk, dj, di)

    d = (sh(d1, -1, 0, 0) + sh(d1, -1, -1, 0) + sh(d1, 0, 0, 0) + sh(d1, 0, -1, 0)) * 0.25
    upd = sh(u1) + (dth / d) * (
        c1 * (sh(xx) - sh(xx, 0, 0, -1))
        + c2 * (sh(xx, 0, 0, 1) - sh(xx, 0, 0, -2))
        + c1 * (sh(xy) - sh(xy, 0, -1, 0))
        + c2 * (sh(xy, 0, 1, 0) - sh(xy, 0, -2, 0))
        + c1 * (sh(xz) - sh(xz, -1, 0, 0))
        + c2 * (sh(xz, 1, 0, 0) - sh(xz, -2, 0, 0))
    )
    return u1.at[2:-2, 2:-2, 2:-2].set(upd)


def long_range(U, V, ROC, c):
    """Fourth-order long-range stencil (paper Listing 7).

    `c` is a length-5 coefficient vector (c0..c4). Interior halo width 4.
    Returns the updated U.
    """
    r = 4

    def sh(arr, dk=0, dj=0, di=0):
        return _sh(arr, r, dk, dj, di)

    lap = c[0] * sh(V)
    for o in range(1, 5):
        lap = lap + c[o] * (sh(V, 0, 0, o) + sh(V, 0, 0, -o))
        lap = lap + c[o] * (sh(V, 0, o, 0) + sh(V, 0, -o, 0))
        lap = lap + c[o] * (sh(V, o, 0, 0) + sh(V, -o, 0, 0))
    upd = 2.0 * sh(V) - sh(U) + sh(ROC) * lap
    return U.at[r:-r, r:-r, r:-r].set(upd)
