"""L2: benchmark wrappers — shapes, dataflow, and repeatability."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand(shape):
    return jnp.asarray(RNG.standard_normal(shape), dtype=jnp.float64)


def test_jacobi_bench_equals_repeated_steps():
    a = rand((10, 16))
    out = model.jacobi2d_bench(a, 0.25, 3)
    want = a
    for _ in range(3):
        want = ref.jacobi2d(want, 0.25)
    np.testing.assert_allclose(out, want, rtol=1e-12)


def test_triad_bench_fixed_point_shape():
    b, c, d = rand((64,)), rand((64,)), rand((64,))
    out = model.triad_bench(b, c, d, 4)
    assert out.shape == (64,)
    # after one application the carry is a fixed point: a = a? no — the
    # carry is fed back as `b`, so 2 reps give b + c*d + ... check one rep
    one = model.triad_bench(b, c, d, 1)
    np.testing.assert_allclose(one, ref.triad(b, c, d), rtol=1e-12)


def test_kahan_bench_returns_scalar():
    a, b = rand((256,)), rand((256,))
    out = model.kahan_ddot_bench(a, b, 2)
    assert out.shape == ()
    s_ref, _ = ref.kahan_ddot(a, b)
    np.testing.assert_allclose(float(out), float(s_ref), rtol=1e-10)


def test_uxx_bench_runs():
    x = [rand((8, 8, 8)) + 2.0 for _ in range(5)]
    out = model.uxx_bench(*x, 2)
    assert out.shape == (8, 8, 8)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_long_range_bench_runs():
    U, V, ROC = rand((12, 12, 12)), rand((12, 12, 12)), rand((12, 12, 12))
    out = model.long_range_bench(U, V, ROC, 2)
    assert out.shape == (12, 12, 12)
    assert bool(jnp.all(jnp.isfinite(out)))
