"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block sizes; fixed-seed numpy data
keeps the comparisons reproducible.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Environment-gated: hypothesis is not part of the offline toolchain in
# every runner; skip the module (loudly) instead of failing collection.
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref

RNG = np.random.default_rng(42)


def rand(shape, dtype, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


DTYPES = [jnp.float32, jnp.float64]


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(min_value=4, max_value=34),
    n=st.integers(min_value=4, max_value=40),
    dt=st.sampled_from(DTYPES),
)
def test_jacobi2d_matches_ref(m, n, dt):
    a = rand((m, n), dt, seed=m * 1000 + n)
    got = pk.jacobi2d(a, 0.25, block_rows=1)
    want = ref.jacobi2d(a, 0.25)
    tol = 1e-5 if dt == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("block_rows", [1, 2, 4, 8])
def test_jacobi2d_block_invariance(block_rows):
    a = rand((18, 24), jnp.float64)
    got = pk.jacobi2d(a, 0.5, block_rows=block_rows)
    want = ref.jacobi2d(a, 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    logn=st.integers(min_value=2, max_value=10),
    dt=st.sampled_from(DTYPES),
)
def test_triad_matches_ref(logn, dt):
    n = 1 << logn
    b = rand((n,), dt, seed=logn)
    c = rand((n,), dt, seed=logn + 100)
    d = rand((n,), dt, seed=logn + 200)
    got = pk.triad(b, c, d, block=min(n, 64))
    # atol covers catastrophic cancellation in b + c*d
    np.testing.assert_allclose(got, ref.triad(b, c, d), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,block", [(64, 64), (256, 64), (1024, 128)])
def test_kahan_matches_ref(n, block):
    a, b = rand((n,), jnp.float64), rand((n,), jnp.float64)
    s, _ = pk.kahan_ddot(a, b, block=block)
    s_ref, _ = ref.kahan_ddot(a, b)
    # compensated sums: block combination changes rounding by < 1 ulp of
    # the condition; compare tightly anyway
    np.testing.assert_allclose(float(s), float(s_ref), rtol=1e-13)


def test_kahan_single_block_bit_identical():
    a, b = rand((128,), jnp.float64), rand((128,), jnp.float64)
    s, c = pk.kahan_ddot(a, b, block=128)
    s_ref, c_ref = ref.kahan_ddot(a, b)
    assert float(s) == float(s_ref)
    assert float(c) == float(c_ref)


def test_kahan_beats_naive_sum():
    # the whole point of Kahan: ill-conditioned sums stay accurate
    n = 4096
    a = jnp.asarray(
        np.concatenate([[1e16], RNG.standard_normal(n - 2), [-1e16]]),
        dtype=jnp.float64,
    )
    b = jnp.ones((n,), jnp.float64)
    s, _ = pk.kahan_ddot(a, b, block=n)
    exact = float(np.sum(np.sort(np.asarray(a, dtype=np.float64))))
    naive = float(jnp.dot(a, b))
    assert abs(float(s) - exact) <= abs(naive - exact)


@pytest.mark.parametrize("m", [8, 12])
@pytest.mark.parametrize("dt", DTYPES)
def test_uxx_matches_ref(m, dt):
    shape = (m, m, m)
    u1, d1, xx, xy, xz = (rand(shape, dt) + 2.0 for _ in range(5))
    got = pk.uxx(u1, d1, xx, xy, xz, 0.5, 0.25, 0.1, block_k=2)
    want = ref.uxx(u1, d1, xx, xy, xz, 0.5, 0.25, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-4 if dt == jnp.float32 else 1e-12)


@pytest.mark.parametrize("m", [12, 16])
def test_long_range_matches_ref(m):
    shape = (m, m, m)
    U, V, ROC = rand(shape, jnp.float64), rand(shape, jnp.float64), rand(shape, jnp.float64)
    c = [0.5, 0.2, 0.1, 0.05, 0.025]
    got = pk.long_range(U, V, ROC, c, block_k=m - 8 if (m - 8) <= 4 else 4)
    want = ref.long_range(U, V, ROC, jnp.asarray(c, dtype=jnp.float64))
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_jacobi_boundary_untouched():
    a = rand((10, 10), jnp.float64)
    out = pk.jacobi2d(a, 1.0, block_rows=2)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[-1]).max()) == 0.0
    assert float(jnp.abs(out[:, 0]).max()) == 0.0
