"""AOT path: lowering must produce loadable HLO text with stable entry
signatures (the Rust runtime parses shapes from the manifest)."""

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float64)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_artifact_defs_cover_all_kernels():
    names = {d["name"] for d in aot.artifact_defs()}
    assert names == {"jacobi2d", "triad", "kahan_ddot", "uxx", "long_range"}


def test_jacobi_artifact_lowers():
    d = next(x for x in aot.artifact_defs() if x["name"] == "jacobi2d")
    # lower with tiny stand-in shapes of the same rank to keep this fast
    small = [
        jax.ShapeDtypeStruct((10, 16), jnp.float64),
        jax.ShapeDtypeStruct((), jnp.float64),
    ]
    lowered = jax.jit(lambda a, s: (model.jacobi2d_bench(a, s, 2),)).lower(*small)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO: no custom-calls that
    # the CPU PJRT client cannot execute
    assert "custom-call" not in text or "Sharding" in text


def test_manifest_row_format():
    d = aot.artifact_defs()[0]
    shapes = ";".join(
        f"{a.dtype}:{','.join(str(s) for s in a.shape)}" for a in d["args"]
    )
    assert shapes.startswith("float64:")
